//! Policy simulators: synchronous, one-step overlap, and fully-async AReaL
//! scheduling over the profile.rs cost models. Used to reproduce the
//! at-scale experiments (Fig 1/3/4/6b, Table 1 hour shapes) that need the
//! paper's 64-node H800 cluster.

use std::collections::HashMap;
use std::collections::VecDeque;

use crate::coordinator::rebalance::{Decision, Observation, RebalanceCfg, RebalanceCtl};
use crate::serve::RoutePolicy;
use crate::util::metrics;
use crate::util::rng::Rng;
use crate::util::stats;

use super::profile::{
    decode_round_s, max_slots, prefill_bucket_tokens, prefill_s, prefill_wave_s, reshard_s,
    train_step_s,
    weight_broadcast_s, weight_stream_stall_s, HardwareProfile, ModelProfile,
};
use super::workload::LenSampler;

#[derive(Debug, Clone)]
pub struct SimConfig {
    pub model: ModelProfile,
    pub hw: HardwareProfile,
    pub n_gpus: usize,
    /// generation fraction of the async split (paper: 0.75)
    pub gen_fraction: f64,
    /// total context (prompt + generation)
    pub ctx: f64,
    pub prompt_len: f64,
    /// global batch in sequences per PPO step
    pub batch_seqs: usize,
    pub n_steps: usize,
    /// max staleness η (async only; None = unbounded)
    pub eta: Option<u64>,
    pub interruptible: bool,
    /// decoding slots per generation device (capped by KV memory)
    pub slot_cap: usize,
    /// responses per prompt (GRPO group sampling; siblings share the
    /// prompt prefix — paper Table 3: 16)
    pub group_size: usize,
    /// serve/-style radix prefix cache on generation devices: sibling
    /// prompts skip the shared prefill; version-tagged entries are
    /// invalidated on every weight update (async policy only)
    pub prefix_cache: bool,
    /// serve::Router request placement across the W generation replicas
    /// (async policy only): `Affinity` keeps a GRPO group's siblings on
    /// one replica so its prompt cache serves G−1 of them; `Fifo` is the
    /// shared-queue baseline that scatters siblings round-robin; `Probe`
    /// scores replicas by measured cached-prefix state minus a load
    /// penalty (the router's probe policy)
    pub route_policy: RoutePolicy,
    /// max requests a dry replica steals from the fullest other inbox per
    /// refill pass once the gate blocks fresh submissions (0 = disabled)
    pub route_steal_max: usize,
    /// `probe` scoring: load penalty per outstanding token
    pub probe_load_penalty: f64,
    /// prompts fall into this many families sharing a family-wide prefix;
    /// a device's KV pool holds at most one family prefix at a time (the
    /// serve/-layer eviction pressure, abstracted)
    pub n_prompt_families: usize,
    /// fraction of the prompt covered by the family-shared prefix
    /// (0.0 = no family structure; every prompt fully distinct)
    pub family_prefix_frac: f64,
    /// replica-failure sweep: remove generation device `.0` when the
    /// trainer publishes version `.1` — its queued and in-flight requests
    /// requeue through the router onto the survivors (zero lost, no
    /// double-charge against the Eq. 3 gate)
    pub fail_replica: Option<(usize, u64)>,
    /// per-hop router↔replica transport latency in seconds (0 = the
    /// in-process inbox model): every productive refill pull pays one
    /// request/response round-trip — two hops — before decode resumes.
    /// This is the `SocketTransport` / multi-node deployment model; sweep
    /// it to predict when remote replicas stop paying off
    pub transport_hop_s: f64,
    /// streamed weight distribution (DESIGN.md §13, async policy): the
    /// trainer publishes and keeps training — each generation replica
    /// pulls the new version as chunked shards over its own link, paying
    /// `weight_stream_stall_s` at its next adoption point instead of the
    /// fleet-wide `weight_broadcast_s` sitting on the trainer's critical
    /// path. Sweep against `transport_hop_s` to find where streamed
    /// shards beat the full-set rebroadcast
    pub weight_stream: bool,
    /// chunk size of the streamed weight shards (bytes; mirrors the live
    /// `weight_chunk_bytes` config key)
    pub weight_chunk_bytes: f64,
    /// dynamic gen/train rebalancing (async policy only): replace the
    /// static `gen_fraction` split with the coordinator's
    /// staleness-headroom threshold policy (`coordinator::rebalance`,
    /// DESIGN.md §7) — at every version bump the policy may gracefully
    /// retire a burst of generation devices into the training pool
    /// (drain, then move their GPUs) or convert training GPUs back into
    /// generation devices (cold caches, one weight broadcast)
    pub rebalance: bool,
    /// mid-run output-length drift: at `.0` of the run's steps the
    /// sampler's mean length is scaled by `.1` (spread and truncation
    /// unchanged) — the workload shape that makes any static
    /// `gen_fraction` wrong in one of the two phases
    pub len_drift: Option<(f64, f64)>,
    /// measured per-token prefill cost in seconds (e.g. the bucketed
    /// `prefill_p{Tb}` wall-clock from BENCH_runtime.json divided by its
    /// token width); 0 keeps the analytic FLOPs model, so default sim
    /// outputs — and the bench_diff gate over them — stay machine-independent
    pub prefill_tok_s: f64,
    pub seed: u64,
}

impl SimConfig {
    pub fn paper_default(model: ModelProfile, n_gpus: usize, ctx: f64) -> Self {
        SimConfig {
            model,
            hw: super::profile::H800,
            n_gpus,
            gen_fraction: 0.75,
            ctx,
            prompt_len: 1024.0,
            // paper: 512 prompts × 16 answers; scale with cluster size so
            // per-device work stays constant in the strong-scaling sweep
            batch_seqs: 512 * 16 * n_gpus / 512,
            n_steps: 8,
            eta: Some(4),
            interruptible: true,
            slot_cap: 256,
            group_size: 16,
            prefix_cache: true,
            route_policy: RoutePolicy::Affinity,
            route_steal_max: 0,
            probe_load_penalty: 0.05,
            n_prompt_families: 1,
            family_prefix_frac: 0.0,
            fail_replica: None,
            transport_hop_s: 0.0,
            weight_stream: false,
            weight_chunk_bytes: 262_144.0,
            rebalance: false,
            len_drift: None,
            prefill_tok_s: 0.0,
            seed: 1,
        }
    }

    /// The ISSUE-5 drift acceptance workload, shared verbatim by
    /// `sim::run::tests::dynamic_rebalance_beats_static_fractions_on_drift`
    /// and `bench_sim`'s `rebalance_drift` records (one constructor, so
    /// the committed baseline numbers always correspond to the tested
    /// scenario): 64 GPUs, 4 long-output steps (mean ≈ 7.9k tokens, the
    /// KV-bound long-CoT regime where generation wants ~0.87 of the
    /// cluster) drifting into 28 short-output steps (mean ≈ 160 tokens,
    /// decode weight-amortized and the trainer's allreduce floor
    /// dominant — balance near half the cluster). Short prompts keep the
    /// per-bump interrupt-recompute tax proportionate, and η = 8 keeps
    /// the gate budget above the fleet's slot capacity so the headroom
    /// signal can swing both ways.
    pub fn drift_rebalance_workload(gen_fraction: f64, rebalance: bool) -> SimConfig {
        let mut c = SimConfig::paper_default(super::profile::MODEL_1_5B, 64, 32768.0);
        c.gen_fraction = gen_fraction;
        c.prompt_len = 128.0;
        c.n_steps = 32;
        c.eta = Some(8);
        c.slot_cap = 64;
        c.len_drift = Some((0.125, 0.02));
        c.rebalance = rebalance;
        c
    }

    /// Tokens of a prompt covered by its family-shared prefix.
    fn family_prefix_len(&self) -> f64 {
        if self.n_prompt_families > 1 {
            (self.family_prefix_frac.clamp(0.0, 1.0)) * self.prompt_len
        } else {
            0.0
        }
    }
}

/// Timeline interval for Fig 1/3 rendering.
#[derive(Debug, Clone)]
pub struct Interval {
    pub device: String,
    pub start: f64,
    pub end: f64,
    pub kind: &'static str, // "gen" | "train" | "reshard" | "interrupt"
}

#[derive(Debug, Clone)]
pub struct SimReport {
    pub policy: &'static str,
    pub total_s: f64,
    pub steps: usize,
    pub tokens_trained: f64,
    /// paper Fig. 4 metric
    pub effective_tps: f64,
    /// seconds the training pool spent inside PPO updates (the
    /// `train_step_s` cost model only — no buffer waits, no weight
    /// broadcast fan-out), mirroring the live trainer's active-time clock
    pub train_active_s: f64,
    /// PPO steps per active-train second — the rate the elastic DP plane
    /// moves when gen→train conversions grow the pool (DESIGN.md §11)
    pub batches_per_s: f64,
    /// tokens_trained / train_active_s (the sim twin of the live
    /// `areal_train_tokens_per_s_active` gauge)
    pub effective_tps_active: f64,
    pub gen_tokens: f64,
    /// mean busy fraction of generation(-phase) devices
    pub gen_util: f64,
    pub interrupts: u64,
    pub mean_staleness: f64,
    pub max_staleness: u64,
    /// prompt prefill tokens actually computed
    pub prefill_tokens: f64,
    /// prompt prefill tokens skipped via the radix prefix cache
    pub cached_prefill_tokens: f64,
    /// committed-context tokens recomputed after weight-update interrupts
    pub recompute_tokens: f64,
    /// cached / (cached + computed) prompt prefill tokens
    pub cache_hit_rate: f64,
    /// request placement policy across replicas ("n/a" for the lockstep
    /// sync/overlap policies, which have no routing plane)
    pub route_policy: &'static str,
    /// requests a dry replica stole from a sibling inbox
    pub stolen_requests: u64,
    /// generation replicas removed mid-run (failure sweep)
    pub failed_replicas: u64,
    /// queued/in-flight requests requeued by replica removals — every one
    /// re-routed onto a survivor, none lost
    pub requeued_requests: u64,
    /// refill pull round-trips that paid transport latency
    /// (`transport_hop_s > 0` only)
    pub transport_hops: u64,
    /// rebalancer conversions: generation devices drained into the
    /// training pool
    pub gen_to_train: u64,
    /// rebalancer conversions: training GPUs brought back as generation
    /// devices
    pub train_to_gen: u64,
    pub timeline: Vec<Interval>,
}

const TIMELINE_DEVICES: usize = 4;
const TIMELINE_STEPS: usize = 3;

// ---------------------------------------------------------------------------
// synchronous (verl-like): all devices generate, reshard, train, reshard

/// Decode a fixed batch of output lengths in lockstep on one device;
/// returns (busy seconds, per-device generated tokens).
fn lockstep_decode(hw: &HardwareProfile, m: &ModelProfile, lens: &[f64],
                   prompt: f64) -> (f64, f64) {
    if lens.is_empty() {
        return (0.0, 0.0);
    }
    let mut sorted = lens.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut t = prefill_s(hw, m, prompt * lens.len() as f64);
    let mut prev = 0.0;
    let mut active = sorted.len();
    let mut tokens = 0.0;
    for &l in &sorted {
        if l > prev {
            let ctx = prompt + (prev + l) / 2.0;
            t += (l - prev) * decode_round_s(hw, m, active, ctx);
            tokens += (l - prev) * active as f64;
            prev = l;
        }
        active -= 1;
    }
    (t, tokens)
}

pub fn run_sync(cfg: &SimConfig) -> SimReport {
    let mut rng = Rng::new(cfg.seed);
    let sampler = LenSampler::for_context(cfg.ctx);
    // tp GPUs form one logical serving device
    let n = (cfg.n_gpus / cfg.model.tp).max(1);
    let mut total = 0.0;
    let mut tokens_trained = 0.0;
    let mut gen_tokens = 0.0;
    let mut busy = 0.0;
    let mut train_active_s = 0.0;
    let mut timeline = Vec::new();
    for step in 0..cfg.n_steps {
        let lens = sampler.sample_n(&mut rng, cfg.batch_seqs);
        // round-robin assignment
        let mut dev_busy = vec![0.0; n];
        let mut dev_tokens = vec![0.0; n];
        for (d, chunk) in lens.chunks(cfg.batch_seqs.div_ceil(n)).enumerate() {
            let (t, tok) = lockstep_decode(&cfg.hw, &cfg.model, chunk, cfg.prompt_len);
            dev_busy[d] = t;
            dev_tokens[d] = tok;
        }
        let gen_time = dev_busy.iter().cloned().fold(0.0, f64::max);
        let step_tokens: f64 = lens.iter().sum();
        let train = train_step_s(&cfg.hw, &cfg.model, step_tokens, n);
        let reshard = reshard_s(&cfg.hw, &cfg.model);
        if step < TIMELINE_STEPS {
            for d in 0..TIMELINE_DEVICES.min(n) {
                timeline.push(Interval {
                    device: format!("gpu{d}"),
                    start: total,
                    end: total + dev_busy[d],
                    kind: "gen",
                });
                timeline.push(Interval {
                    device: format!("gpu{d}"),
                    start: total + gen_time + reshard,
                    end: total + gen_time + reshard + train,
                    kind: "train",
                });
            }
        }
        total += gen_time + 2.0 * reshard + train;
        busy += dev_busy.iter().sum::<f64>();
        train_active_s += train;
        tokens_trained += step_tokens;
        gen_tokens += dev_tokens.iter().sum::<f64>();
    }
    SimReport {
        policy: "sync",
        total_s: total,
        steps: cfg.n_steps,
        tokens_trained,
        effective_tps: tokens_trained / total,
        train_active_s,
        batches_per_s: cfg.n_steps as f64 / train_active_s.max(1e-12),
        effective_tps_active: tokens_trained / train_active_s.max(1e-12),
        gen_tokens,
        gen_util: busy / (n as f64 * total),
        interrupts: 0,
        mean_staleness: 0.0,
        max_staleness: 0,
        prefill_tokens: cfg.prompt_len * (cfg.n_steps * cfg.batch_seqs) as f64,
        cached_prefill_tokens: 0.0,
        recompute_tokens: 0.0,
        cache_hit_rate: 0.0,
        route_policy: "n/a",
        stolen_requests: 0,
        failed_replicas: 0,
        requeued_requests: 0,
        transport_hops: 0,
        gen_to_train: 0,
        train_to_gen: 0,
        timeline,
    }
}

// ---------------------------------------------------------------------------
// one-step overlap: split cluster, batch i+1 generated (whole, with the
// previous weights) while batch i trains — staleness fixed at 1

pub fn run_overlap(cfg: &SimConfig) -> SimReport {
    let mut rng = Rng::new(cfg.seed);
    let sampler = LenSampler::for_context(cfg.ctx);
    let n_gen_gpus = ((cfg.n_gpus as f64) * cfg.gen_fraction).round().max(1.0) as usize;
    let n_train = (cfg.n_gpus - n_gen_gpus).max(1);
    let n_gen = (n_gen_gpus / cfg.model.tp).max(1);
    let mut total = 0.0;
    let mut tokens_trained = 0.0;
    let mut gen_tokens = 0.0;
    let mut gen_busy = 0.0;
    let mut train_active_s = 0.0;
    let mut timeline = Vec::new();
    for step in 0..cfg.n_steps {
        let lens = sampler.sample_n(&mut rng, cfg.batch_seqs);
        let mut dev_busy = vec![0.0; n_gen];
        for (d, chunk) in lens.chunks(cfg.batch_seqs.div_ceil(n_gen)).enumerate() {
            let (t, _tok) = lockstep_decode(&cfg.hw, &cfg.model, chunk, cfg.prompt_len);
            dev_busy[d] = t;
        }
        let gen_time = dev_busy.iter().cloned().fold(0.0, f64::max);
        let step_tokens: f64 = lens.iter().sum();
        let train_core = train_step_s(&cfg.hw, &cfg.model, step_tokens, n_train);
        let train = train_core + weight_broadcast_s(&cfg.hw, &cfg.model, n_gen);
        train_active_s += train_core;
        // pipelined: limited by the slower stage
        let step_time = gen_time.max(train);
        if step < TIMELINE_STEPS {
            for d in 0..TIMELINE_DEVICES.min(n_gen) {
                timeline.push(Interval {
                    device: format!("gen{d}"),
                    start: total,
                    end: total + dev_busy[d],
                    kind: "gen",
                });
            }
            timeline.push(Interval {
                device: "trainer".into(),
                start: total,
                end: total + train,
                kind: "train",
            });
        }
        total += step_time;
        gen_busy += dev_busy.iter().sum::<f64>();
        tokens_trained += step_tokens;
        gen_tokens += step_tokens;
    }
    SimReport {
        policy: "overlap",
        total_s: total,
        steps: cfg.n_steps,
        tokens_trained,
        effective_tps: tokens_trained / total,
        train_active_s,
        batches_per_s: cfg.n_steps as f64 / train_active_s.max(1e-12),
        effective_tps_active: tokens_trained / train_active_s.max(1e-12),
        gen_tokens,
        gen_util: gen_busy / (n_gen as f64 * total),
        interrupts: 0,
        mean_staleness: 1.0,
        max_staleness: 1,
        prefill_tokens: cfg.prompt_len * (cfg.n_steps * cfg.batch_seqs) as f64,
        cached_prefill_tokens: 0.0,
        recompute_tokens: 0.0,
        cache_hit_rate: 0.0,
        route_policy: "n/a",
        stolen_requests: 0,
        failed_replicas: 0,
        requeued_requests: 0,
        transport_hops: 0,
        gen_to_train: 0,
        train_to_gen: 0,
        timeline,
    }
}

// ---------------------------------------------------------------------------
// fully-async AReaL: event-driven over gen devices + trainer

#[derive(Debug, Clone)]
struct SimSeq {
    /// GRPO group this request belongs to (requeued on replica failure)
    gid: u64,
    remaining: f64,
    produced: f64,
    born_version: u64,
}

struct GenDevice {
    slots: Vec<SimSeq>,
    /// decode paused until (prefill / interrupt recompute)
    resume_at: f64,
    busy_s: f64,
    pending_weights: bool,
    /// groups whose prompt prefix this replica's (serve/-style) radix
    /// cache holds, tagged with the weight version that computed the KV;
    /// a version mismatch is a cache miss — update_weights invalidates
    /// version-tagged blocks
    cached: HashMap<u64, u64>,
    /// the one prompt-family prefix this device's pool currently retains
    /// (family, version) — the serve/ layer's eviction pressure abstracted
    /// to a single-entry cache; serving another family displaces it
    family_cached: Option<(u64, u64)>,
}

/// The serve::Router model: whole GRPO groups are submitted through the
/// frontend and placed into per-replica inboxes by the routing policy —
/// `Affinity` co-locates a group's G siblings on the least-queued alive
/// replica, `Fifo` scatters them round-robin (the shared-queue baseline),
/// and `Probe` scores alive replicas by measured family-prefix warmth
/// minus an outstanding-token load penalty. Replica loss flips `alive`;
/// the dead inbox requeues through the same placement.
struct SimRouter {
    inboxes: Vec<VecDeque<u64>>,
    alive: Vec<bool>,
    next_group: u64,
    rr: usize,
    policy: RoutePolicy,
}

impl SimRouter {
    fn new(n: usize, policy: RoutePolicy) -> SimRouter {
        SimRouter {
            inboxes: (0..n).map(|_| VecDeque::new()).collect(),
            alive: vec![true; n],
            next_group: 0,
            rr: 0,
            policy,
        }
    }

    fn family_of(gid: u64, cfg: &SimConfig) -> u64 {
        gid % cfg.n_prompt_families.max(1) as u64
    }

    /// Place one request of group `gid` on an alive replica.
    fn route_one(&mut self, gid: u64, devices: &[GenDevice], version: u64,
                 cfg: &SimConfig) -> usize {
        let n = self.inboxes.len();
        let start = self.rr % n;
        self.rr += 1;
        match self.policy {
            RoutePolicy::Fifo => {
                // round-robin over the alive replicas
                for k in 0..n {
                    let i = (start + k) % n;
                    if self.alive[i] {
                        return i;
                    }
                }
                unreachable!("no alive replicas");
            }
            RoutePolicy::Affinity => {
                // least-queued alive replica, round-robin tie-break
                let mut best: Option<usize> = None;
                for k in 0..n {
                    let i = (start + k) % n;
                    if !self.alive[i] {
                        continue;
                    }
                    let better = match best {
                        None => true,
                        Some(b) => self.inboxes[i].len() < self.inboxes[b].len(),
                    };
                    if better {
                        best = Some(i);
                    }
                }
                best.expect("no alive replicas")
            }
            RoutePolicy::Probe => {
                // measured family-prefix warmth minus a load penalty, the
                // router's probe score over the simulated fleet
                let fam = Self::family_of(gid, cfg);
                let shared = cfg.family_prefix_len();
                let mut best: Option<(usize, f64)> = None;
                for k in 0..n {
                    let i = (start + k) % n;
                    if !self.alive[i] {
                        continue;
                    }
                    let cached = match devices[i].cached.get(&gid) {
                        Some(&v) if v == version && cfg.prefix_cache => cfg.prompt_len,
                        _ => match devices[i].family_cached {
                            Some((f, v)) if f == fam && v == version && cfg.prefix_cache => {
                                shared
                            }
                            _ => 0.0,
                        },
                    };
                    let load = (self.inboxes[i].len() + devices[i].slots.len()) as f64
                        * cfg.prompt_len;
                    let score = cached - cfg.probe_load_penalty * load;
                    let better = match best {
                        None => true,
                        Some((_, s)) => score > s,
                    };
                    if better {
                        best = Some((i, score));
                    }
                }
                best.expect("no alive replicas").0
            }
        }
    }

    /// Route one whole group of `g` sibling requests.
    fn submit_group(&mut self, g: usize, devices: &[GenDevice], version: u64,
                    cfg: &SimConfig) {
        let gid = self.next_group;
        self.next_group += 1;
        match self.policy {
            RoutePolicy::Fifo => {
                for _ in 0..g {
                    let i = self.route_one(gid, devices, version, cfg);
                    self.inboxes[i].push_back(gid);
                }
            }
            _ => {
                // affinity/probe co-locate the whole group
                let i = self.route_one(gid, devices, version, cfg);
                for _ in 0..g {
                    self.inboxes[i].push_back(gid);
                }
            }
        }
    }

    /// Remove replica `d` from the fleet: requeue its queued requests onto
    /// the survivors via normal placement. Returns how many were requeued
    /// (none lost, none re-charged against the gate).
    fn remove_replica(&mut self, d: usize, orphans: Vec<u64>,
                      devices: &[GenDevice], version: u64, cfg: &SimConfig) -> u64 {
        self.alive[d] = false;
        let queued: Vec<u64> = self.inboxes[d].drain(..).collect();
        let mut n = 0;
        for gid in queued.into_iter().chain(orphans) {
            let i = self.route_one(gid, devices, version, cfg);
            self.inboxes[i].push_back(gid);
            n += 1;
        }
        n
    }
}

/// Prompt-prefill accounting for one refill wave.
struct RefillOutcome {
    paid_prompt_tokens: f64,
    cached_prompt_tokens: f64,
    stolen: u64,
    /// transport round-trips paid by this wave (remote-replica model)
    hops: u64,
}

/// Refill replica `d`'s empty slots from its router inbox. When the inbox
/// runs dry, first ask the frontend for a fresh group — reserved against
/// the Eq. 3 gate atomically, whole group or nothing, exactly as the real
/// controller does — and once the gate blocks, steal a bounded batch from
/// the back of the fullest sibling inbox. Prompt prefill is paid only on
/// cache misses: a group already served on this replica under the current
/// weights rides the per-group radix entry, and a same-family prompt
/// rides the family prefix while the pool retains it (serving another
/// family displaces it — the eviction pressure that makes measured
/// probing matter).
#[allow(clippy::too_many_arguments)]
fn refill_device(d: usize, devices: &mut [GenDevice], router: &mut SimRouter,
                 rng: &mut Rng, submitted: &mut u64, version: u64, now: f64,
                 sampler: &LenSampler, cfg: &SimConfig,
                 slots_per_dev: usize) -> RefillOutcome {
    let b = cfg.batch_seqs as u64;
    // atomic whole-group reservation: every index in submitted..+g must
    // satisfy Eq. 3, which reduces to checking the last one
    let admits_group = |submitted: u64, g: u64| match cfg.eta {
        None => true,
        Some(eta) => (submitted + g - 1) / b <= version + eta,
    };
    let g = cfg.group_size.max(1) as u64;
    let mut paid = 0.0;
    // bucket-rounded fresh tokens actually dispatched to the prefill
    // executables (the paid tokens, each sequence rounded up to its
    // `prefill_p{Tb}` bucket) — this is what the wave bills for, and what
    // `areal_prefill_skipped_tokens_total` measures the complement of live
    let mut charged = 0.0;
    let mut cached = 0.0;
    let mut stolen = 0u64;
    let mut popped = false;
    let mut steal_budget = cfg.route_steal_max;
    while devices[d].slots.len() < slots_per_dev {
        let Some(gid) = router.inboxes[d].pop_front() else {
            // inbox dry: ask the frontend for a fresh whole group
            if admits_group(*submitted, g) {
                *submitted += g;
                router.submit_group(g as usize, devices, version, cfg);
                continue;
            }
            // gate blocked: steal a bounded batch from the fullest
            // sibling inbox (back of queue, like the real router)
            if steal_budget == 0 {
                break;
            }
            let victim = (0..router.inboxes.len())
                .filter(|&i| i != d && router.alive[i])
                .max_by_key(|&i| router.inboxes[i].len());
            let Some(v) = victim else { break };
            if router.inboxes[v].is_empty() {
                break;
            }
            while steal_budget > 0 {
                let Some(sg) = router.inboxes[v].pop_back() else { break };
                router.inboxes[d].push_back(sg);
                steal_budget -= 1;
                stolen += 1;
            }
            continue;
        };
        let dev = &mut devices[d];
        let fam = SimRouter::family_of(gid, cfg);
        let shared = cfg.family_prefix_len();
        if cfg.prefix_cache && dev.cached.get(&gid) == Some(&version) {
            cached += cfg.prompt_len;
        } else {
            // family-prefix hit covers the shared head of the prompt;
            // serving this family displaces whatever the pool held
            let shared_hit = cfg.prefix_cache
                && matches!(dev.family_cached, Some((f, v)) if f == fam && v == version);
            let hit = if shared_hit { shared } else { 0.0 };
            cached += hit;
            paid += cfg.prompt_len - hit;
            charged += prefill_bucket_tokens(cfg.prompt_len - hit);
            if cfg.prefix_cache {
                dev.cached.insert(gid, version);
                dev.family_cached = Some((fam, version));
            }
        }
        dev.slots.push(SimSeq {
            gid,
            remaining: sampler.sample(rng),
            produced: 0.0,
            born_version: version,
        });
        popped = true;
    }
    if paid > 0.0 {
        // prefill cost for the uncached prompt tokens only, billed at
        // bucket granularity (measured per-token kernel cost when supplied)
        let t = prefill_wave_s(&cfg.hw, &cfg.model, charged, cfg.prefill_tok_s);
        let dev = &mut devices[d];
        dev.resume_at = dev.resume_at.max(now) + t;
    }
    let mut hops = 0u64;
    if popped && cfg.transport_hop_s > 0.0 {
        // remote-replica model: a productive refill is one pull RPC —
        // request out, requests back — before decode resumes on this
        // device (submission-side hops are pipelined by the router and
        // never block a replica, so pulls are the latency that matters)
        hops = 1;
        let dev = &mut devices[d];
        dev.resume_at = dev.resume_at.max(now) + 2.0 * cfg.transport_hop_s;
    }
    RefillOutcome { paid_prompt_tokens: paid, cached_prompt_tokens: cached, stolen, hops }
}

/// One streamed weight-set adoption (DESIGN.md §13): returns the stall
/// the replica pays and accounts the chunks it pulled on the same
/// `areal_weight_chunks_total` series the live `WeightStreamer`
/// increments per served chunk.
fn stream_adoption_s(cfg: &SimConfig) -> f64 {
    let chunks =
        (cfg.model.weight_bytes() / cfg.weight_chunk_bytes.max(1.0)).ceil() as u64;
    metrics::inc("areal_weight_chunks_total", chunks.max(1));
    weight_stream_stall_s(&cfg.hw, &cfg.model, cfg.transport_hop_s, cfg.weight_chunk_bytes)
}

/// One refill pass over the whole fleet — every alive replica serves its
/// inbox (non-interruptible replicas waiting on a weight apply are
/// skipped until they drain).
#[allow(clippy::too_many_arguments)]
fn refill_all(devices: &mut [GenDevice], router: &mut SimRouter, rng: &mut Rng,
              submitted: &mut u64, version: u64, now: f64, sampler: &LenSampler,
              cfg: &SimConfig, slots_per_dev: usize) -> RefillOutcome {
    let mut out = RefillOutcome {
        paid_prompt_tokens: 0.0,
        cached_prompt_tokens: 0.0,
        stolen: 0,
        hops: 0,
    };
    for d in 0..devices.len() {
        if !router.alive[d] {
            continue;
        }
        if devices[d].pending_weights {
            if devices[d].slots.is_empty() {
                devices[d].pending_weights = false; // weights applied
                if cfg.weight_stream {
                    // the drained replica pulls the new shards over its
                    // own link before it can decode again
                    let stall = stream_adoption_s(cfg);
                    devices[d].resume_at = devices[d].resume_at.max(now) + stall;
                }
            } else {
                continue; // draining
            }
        }
        if devices[d].slots.len() < slots_per_dev {
            let o = refill_device(d, devices, router, rng, submitted, version, now,
                                  sampler, cfg, slots_per_dev);
            out.paid_prompt_tokens += o.paid_prompt_tokens;
            out.cached_prompt_tokens += o.cached_prompt_tokens;
            out.stolen += o.stolen;
            out.hops += o.hops;
        }
    }
    out
}

impl GenDevice {
    fn next_completion(&self, hw: &HardwareProfile, m: &ModelProfile, now: f64,
                       prompt: f64) -> Option<f64> {
        if self.slots.is_empty() {
            return None;
        }
        let min_rem = self
            .slots
            .iter()
            .map(|s| s.remaining)
            .fold(f64::INFINITY, f64::min);
        let mean_ctx = prompt
            + stats::mean(&self.slots.iter().map(|s| s.produced).collect::<Vec<_>>())
            + min_rem / 2.0;
        let rho = decode_round_s(hw, m, self.slots.len(), mean_ctx);
        Some(now.max(self.resume_at) + min_rem * rho)
    }

    /// Advance decoding to `t`, producing tokens; returns completed seqs.
    fn advance_to(&mut self, hw: &HardwareProfile, m: &ModelProfile, now: f64,
                  t: f64, prompt: f64) -> Vec<SimSeq> {
        let mut done = Vec::new();
        if self.slots.is_empty() {
            return done;
        }
        let start = now.max(self.resume_at);
        if t <= start {
            return done;
        }
        let mean_ctx = prompt
            + stats::mean(&self.slots.iter().map(|s| s.produced).collect::<Vec<_>>());
        let rho = decode_round_s(hw, m, self.slots.len(), mean_ctx);
        let rounds = (t - start) / rho;
        self.busy_s += t - start;
        let mut i = 0;
        while i < self.slots.len() {
            let s = &mut self.slots[i];
            s.produced += rounds.min(s.remaining);
            s.remaining -= rounds.min(s.remaining);
            if s.remaining <= 1e-9 {
                done.push(self.slots.swap_remove(i));
            } else {
                i += 1;
            }
        }
        done
    }
}

pub fn run_async(cfg: &SimConfig) -> SimReport {
    let mut rng = Rng::new(cfg.seed);
    let base_sampler = LenSampler::for_context(cfg.ctx);
    let mut sampler = base_sampler.clone();
    let mut drifted = false;
    let hw = &cfg.hw;
    let m = &cfg.model;
    let n_gen_gpus = ((cfg.n_gpus as f64) * cfg.gen_fraction).round().max(1.0) as usize;
    // training GPUs are a *pool* under rebalancing: a drained gen device
    // moves its tp GPUs here, a reactivation takes them back
    let mut n_train = (cfg.n_gpus - n_gen_gpus).max(1);
    // tp GPUs form one logical generation device (weights sharded)
    let n_gen = (n_gen_gpus / m.tp).max(1);
    // with rebalancing on, pre-build device slots up to the cluster
    // ceiling — everything but a training-pool floor of one eighth of the
    // GPUs (at least one tp group), which keeps a runaway grow decision
    // from starving training into pathologically long steps. Devices
    // beyond the startup split begin parked: dead to the router, their
    // GPUs counted in the training pool, so the dynamic policy can
    // *exceed* the static split in a generation-bound phase, not just
    // undercut it.
    let train_floor = (cfg.n_gpus / 8).max(m.tp);
    let n_dev = if cfg.rebalance {
        n_gen.max((cfg.n_gpus.saturating_sub(train_floor) / m.tp).max(1))
    } else {
        n_gen
    };
    let slots_per_dev = cfg.slot_cap.min(max_slots(hw, m, cfg.ctx)).max(1);

    let mut submitted: u64 = 0;
    let mut version: u64 = 0;

    let mut devices: Vec<GenDevice> = (0..n_dev)
        .map(|_| GenDevice {
            slots: Vec::with_capacity(slots_per_dev),
            resume_at: 0.0,
            busy_s: 0.0,
            pending_weights: false,
            cached: HashMap::new(),
            family_cached: None,
        })
        .collect();
    let mut router = SimRouter::new(n_dev, cfg.route_policy);
    // devices beyond the startup split start in the training pool
    let mut parked: Vec<usize> = Vec::new();
    for d in n_gen..n_dev {
        router.alive[d] = false;
        parked.push(d);
    }
    // gen devices draining toward the training pool (alive already false:
    // no refills, no routing; their in-flight slots finish first)
    let mut retiring = vec![false; n_dev];
    let mut ctl = cfg.rebalance.then(|| {
        let mut rcfg = RebalanceCfg::new(1, n_dev, 1.0);
        // the sim evaluates once per version bump (coarse ticks), so one
        // agreeing observation acts; the dead band still blocks thrash
        rcfg.patience = 1;
        RebalanceCtl::new(rcfg)
    });
    // conversions move a burst per decision: at version-bump cadence,
    // single-device steps could never track a mid-run workload drift
    let convert_burst = (n_dev / 8).max(1);
    let mut gen_to_train = 0u64;
    let mut train_to_gen = 0u64;
    let mut stolen_requests = 0u64;
    let mut transport_hops = 0u64;
    let mut failed_replicas = 0u64;
    let mut requeued_requests = 0u64;

    // buffer of finished sequences: (len, born_version)
    let mut buffer: Vec<(f64, u64)> = Vec::new();
    let mut trainer_busy_until: Option<f64> = None;
    let mut steps_done = 0usize;
    let mut now = 0.0;
    // generation-device-seconds actually in the gen role (denominator of
    // gen_util; equals n_gen·total_s when the fleet never changes)
    let mut gen_dev_seconds = 0.0;
    let mut tokens_trained = 0.0;
    let mut train_active_s = 0.0;
    let mut gen_tokens = 0.0;
    let mut completions = 0u64;
    let mut interrupts = 0u64;
    let mut staleness_samples: Vec<f64> = Vec::new();
    let mut max_stale = 0u64;
    let mut timeline = Vec::new();
    let mut prefill_tokens = 0.0;
    let mut cached_prefill_tokens = 0.0;
    let mut recompute_tokens = 0.0;

    // initial fill
    let o = refill_all(&mut devices, &mut router, &mut rng, &mut submitted,
                       version, now, &sampler, cfg, slots_per_dev);
    prefill_tokens += o.paid_prompt_tokens;
    cached_prefill_tokens += o.cached_prompt_tokens;
    stolen_requests += o.stolen;
    transport_hops += o.hops;

    let max_iters = cfg.n_steps * cfg.batch_seqs * 4 + 10_000;
    let mut iters = 0;
    while steps_done < cfg.n_steps {
        iters += 1;
        if iters > max_iters {
            panic!("async sim failed to converge (gate deadlock?)");
        }
        // start training if possible
        if trainer_busy_until.is_none() && buffer.len() >= cfg.batch_seqs {
            // oldest-first
            buffer.sort_by_key(|&(_, v)| v);
            let batch: Vec<(f64, u64)> = buffer.drain(..cfg.batch_seqs).collect();
            let toks: f64 = batch.iter().map(|&(l, _)| l).sum();
            for &(_, born) in &batch {
                let s = version.saturating_sub(born);
                staleness_samples.push(s as f64);
                max_stale = max_stale.max(s);
                // same series the live trainer records, from the modeled
                // clock — `areal sim` summaries line up with live runs
                metrics::observe("areal_staleness_versions", s as f64);
            }
            // live counts: the training pool and the broadcast fan-out
            // both follow the rebalancer's conversions
            let gen_now = router.alive.iter().filter(|a| **a).count()
                + retiring.iter().filter(|r| **r).count();
            let train_core = train_step_s(hw, m, toks, n_train);
            // streamed shards take the fan-out off the trainer's critical
            // path entirely: the publish is pull-based, each replica pays
            // its own adoption stall (charged at its adoption point below)
            let dur = if cfg.weight_stream {
                train_core
            } else {
                train_core + weight_broadcast_s(hw, m, gen_now.max(1))
            };
            train_active_s += train_core;
            trainer_busy_until = Some(now + dur);
            tokens_trained += toks;
            metrics::observe("areal_train_step_seconds", dur);
            metrics::inc("areal_train_tokens_total", toks as u64);
            if steps_done < TIMELINE_STEPS {
                timeline.push(Interval {
                    device: "trainer".into(),
                    start: now,
                    end: now + dur,
                    kind: "train",
                });
            }
        }

        // next event
        let mut t_next = f64::INFINITY;
        for dev in devices.iter() {
            if let Some(t) = dev.next_completion(hw, m, now, cfg.prompt_len) {
                t_next = t_next.min(t);
            }
        }
        if let Some(t) = trainer_busy_until {
            t_next = t_next.min(t);
        }
        if !t_next.is_finite() {
            if router.inboxes.iter().any(|q| !q.is_empty()) {
                // the router can land a group in an inbox *after* that
                // replica's refill already ran this pass — serve the
                // stranded requests before declaring starvation
                let o = refill_all(&mut devices, &mut router, &mut rng,
                                   &mut submitted, version, now, &sampler, cfg,
                                   slots_per_dev);
                prefill_tokens += o.paid_prompt_tokens;
                cached_prefill_tokens += o.cached_prompt_tokens;
                stolen_requests += o.stolen;
                transport_hops += o.hops;
                continue;
            }
            // all devices empty, all inboxes dry, trainer idle: gate
            // blocked without a pending version bump => starvation (η too
            // small relative to inflight capacity). This state can only be
            // escaped if buffer has data (handled above), so it is a
            // genuine deadlock.
            panic!(
                "async sim starved: no device active, trainer idle \
                 (buffer {} / batch {})",
                buffer.len(),
                cfg.batch_seqs
            );
        }

        // advance all devices to t_next
        for dev in devices.iter_mut() {
            for done in dev.advance_to(hw, m, now, t_next, cfg.prompt_len) {
                gen_tokens += done.produced;
                completions += 1;
                buffer.push((done.produced, done.born_version));
            }
        }
        gen_dev_seconds += (router
            .alive
            .iter()
            .zip(&retiring)
            .filter(|(a, r)| **a || **r)
            .count() as f64)
            * (t_next - now);
        now = t_next;

        // a retiring device whose slots have drained completes its
        // conversion: its GPUs join the training pool, its caches go cold
        for d in 0..n_dev {
            if retiring[d] && devices[d].slots.is_empty() {
                retiring[d] = false;
                devices[d].cached.clear();
                devices[d].family_cached = None;
                devices[d].pending_weights = false;
                parked.push(d);
                n_train += m.tp;
                gen_to_train += 1;
            }
        }

        // trainer completion => new version => weight update
        if trainer_busy_until.is_some_and(|t| t <= now + 1e-12) {
            trainer_busy_until = None;
            version += 1;
            steps_done += 1;
            if metrics::enabled() {
                // live-name parity (DESIGN.md §10): the gate and router
                // gauges the coordinator emits, fed from the modeled state
                // at the same cadence (the version bump)
                if let Some(eta) = cfg.eta {
                    let b = cfg.batch_seqs as u64;
                    let ceiling = b * (version + eta + 1);
                    let headroom = ceiling.saturating_sub(submitted) as f64 / b as f64;
                    metrics::set("areal_gate_headroom_batches", headroom);
                    metrics::set(
                        "areal_gate_occupancy",
                        (1.0 - headroom / (eta + 1) as f64).clamp(0.0, 1.0),
                    );
                }
                let depth: usize = router.inboxes.iter().map(|q| q.len()).sum();
                metrics::set("areal_inbox_depth", depth as f64);
            }
            // replica-failure sweep: the scheduled device leaves the fleet
            // now — its in-flight decode is lost (the work, not the
            // requests), and every queued/in-flight request requeues
            // through normal placement onto the survivors; the gate is
            // not re-charged (they were already submitted)
            if let Some((fd, fv)) = cfg.fail_replica {
                // guard on the LIVE alive count, not the startup split:
                // under rebalancing the fleet is dynamic, and the failure
                // sweep must never take down the last serving device
                let alive_now = router.alive.iter().filter(|a| **a).count();
                if version == fv && fd < devices.len() && router.alive[fd]
                    && alive_now > 1
                {
                    let orphans: Vec<u64> =
                        devices[fd].slots.drain(..).map(|s| s.gid).collect();
                    requeued_requests +=
                        router.remove_replica(fd, orphans, &devices, version, cfg);
                    failed_replicas += 1;
                }
            }
            for (d, dev) in devices.iter_mut().enumerate() {
                if !router.alive[d] {
                    continue;
                }
                // update_weights invalidation: every version-tagged cache
                // entry is now stale and can never hit again — including
                // the resident family prefix
                dev.cached.retain(|_, v| *v >= version);
                if matches!(dev.family_cached, Some((_, v)) if v < version) {
                    dev.family_cached = None;
                }
                if cfg.weight_stream && cfg.interruptible {
                    // interruptible adoption happens now: the replica
                    // pulls the new shards before resuming (idle replicas
                    // too — their next admission runs under the new
                    // version). Non-interruptible replicas adopt when
                    // they drain (refill_all's pending_weights clear).
                    let stall = stream_adoption_s(cfg);
                    dev.resume_at = dev.resume_at.max(now) + stall;
                }
                if cfg.interruptible {
                    if !dev.slots.is_empty() {
                        interrupts += 1;
                        // KV recompute of the committed context of every
                        // in-flight sequence (the paper's interrupt cost)
                        let committed: f64 = dev
                            .slots
                            .iter()
                            .map(|s| cfg.prompt_len + s.produced)
                            .sum();
                        recompute_tokens += committed;
                        // interrupt KV recompute is a fully-uncached wave
                        // (stale pool entries were just invalidated), each
                        // sequence billed at its bucket width
                        let charged: f64 = dev
                            .slots
                            .iter()
                            .map(|s| prefill_bucket_tokens(cfg.prompt_len + s.produced))
                            .sum();
                        let t = prefill_wave_s(hw, m, charged, cfg.prefill_tok_s);
                        dev.resume_at = dev.resume_at.max(now) + t;
                        if steps_done <= TIMELINE_STEPS && d < TIMELINE_DEVICES {
                            timeline.push(Interval {
                                device: format!("gen{d}"),
                                start: now,
                                end: now + t,
                                kind: "interrupt",
                            });
                        }
                    }
                } else {
                    // non-interruptible: stop refilling; weights apply once
                    // the device drains (SGLang-style update_weights)
                    dev.pending_weights = true;
                }
            }

            // mid-run workload drift: the output-length distribution
            // shifts once, at the configured fraction of the run
            if let Some((frac, scale)) = cfg.len_drift {
                if !drifted && steps_done as f64 >= frac * cfg.n_steps as f64 {
                    sampler = base_sampler.scale_mean(scale);
                    drifted = true;
                }
            }

            // staleness-driven rebalancing (DESIGN.md §7), evaluated at
            // the version bump — the cadence at which the Eq. 3 headroom
            // signal is well-defined. Same threshold policy as the live
            // coordinator; the sim's generation-backlog signal is trainer
            // starvation (the buffer cannot seed the next step).
            if let Some(ctl) = ctl.as_mut() {
                if steps_done < cfg.n_steps {
                    let b = cfg.batch_seqs as u64;
                    let headroom = cfg.eta.map(|eta| {
                        let ceiling = b * (version + eta + 1);
                        ceiling.saturating_sub(submitted) as f64 / b as f64
                    });
                    let alive_count = router.alive.iter().filter(|a| **a).count();
                    let gen_capacity =
                        alive_count + retiring.iter().filter(|r| **r).count();
                    let o = Observation {
                        headroom_batches: headroom,
                        gen_backlogged: buffer.len() < cfg.batch_seqs,
                        n_gen: gen_capacity,
                    };
                    match ctl.observe(o) {
                        Decision::Hold => {}
                        Decision::GenToTrain => {
                            // gracefully retire the emptiest alive devices:
                            // no more routing or refills now (their queued
                            // requests requeue whole onto the survivors),
                            // GPUs move once the in-flight slots drain. At
                            // least one serving device always remains, and
                            // only one wave drains at a time — starting new
                            // retirements while a wave is still draining
                            // would cascade far past the target on a stale
                            // capacity signal.
                            let mut burst = if retiring.iter().any(|r| *r) {
                                0
                            } else {
                                convert_burst.min(alive_count.saturating_sub(1))
                            };
                            while burst > 0 {
                                let victim = (0..n_dev)
                                    .filter(|&d| router.alive[d] && !retiring[d])
                                    .min_by_key(|&d| devices[d].slots.len());
                                let Some(v) = victim else { break };
                                requeued_requests += router.remove_replica(
                                    v,
                                    Vec::new(),
                                    &devices,
                                    version,
                                    cfg,
                                );
                                retiring[v] = true;
                                burst -= 1;
                            }
                        }
                        Decision::TrainToGen => {
                            let mut burst = convert_burst;
                            while burst > 0 {
                                // cancel an in-progress retirement first —
                                // free (caches intact, GPUs never moved)
                                if let Some(d) = (0..n_dev).find(|&d| retiring[d]) {
                                    retiring[d] = false;
                                    router.alive[d] = true;
                                    burst -= 1;
                                    continue;
                                }
                                // then reactivate parked devices while the
                                // training pool keeps its floor (a whole tp
                                // group must come out without dipping below)
                                if n_train < train_floor + m.tp {
                                    break;
                                }
                                let Some(d) = parked.pop() else { break };
                                router.alive[d] = true;
                                devices[d].cached.clear();
                                devices[d].family_cached = None;
                                devices[d].pending_weights = false;
                                // cold join: the full weight set crosses
                                // the wire before the reactivated device
                                // can decode — streamed as chunked shards
                                // or as one point-to-point broadcast
                                let join_s = if cfg.weight_stream {
                                    stream_adoption_s(cfg)
                                } else {
                                    weight_broadcast_s(hw, m, 1)
                                };
                                devices[d].resume_at =
                                    devices[d].resume_at.max(now) + join_s;
                                n_train -= m.tp;
                                train_to_gen += 1;
                                burst -= 1;
                            }
                        }
                    }
                }
            }
        }

        // refills
        let o = refill_all(&mut devices, &mut router, &mut rng, &mut submitted,
                           version, now, &sampler, cfg, slots_per_dev);
        prefill_tokens += o.paid_prompt_tokens;
        cached_prefill_tokens += o.cached_prompt_tokens;
        stolen_requests += o.stolen;
        transport_hops += o.hops;
    }

    let busy: f64 = devices.iter().map(|d| d.busy_s).sum();
    let prompt_total = prefill_tokens + cached_prefill_tokens;
    if metrics::enabled() {
        metrics::inc("areal_gen_tokens_total", gen_tokens as u64);
        metrics::inc("areal_rebalance_to_train_total", gen_to_train);
        metrics::inc("areal_rebalance_to_gen_total", train_to_gen);
        metrics::set("areal_train_tokens_per_s", tokens_trained / now);
        metrics::set("areal_train_tokens_per_s_active",
                     tokens_trained / train_active_s.max(1e-12));
        // name parity with the live DP plane: pool tp-groups beyond the
        // lead count as registered DP ranks (final value of the run)
        metrics::set("areal_dp_workers",
                     ((n_train / m.tp).max(1) - 1) as f64);
        // modeled request-latency series: time-to-first-token is the cold
        // prefill of one prompt — bucket-rounded like the paged executables,
        // billed at the measured per-token rate when one is configured
        let ttft = prefill_wave_s(hw, m, prefill_bucket_tokens(cfg.prompt_len),
                                  cfg.prefill_tok_s);
        metrics::observe("areal_ttft_seconds", ttft);
        if completions > 0 {
            let mean_decode = busy * slots_per_dev as f64 / completions as f64;
            metrics::observe("areal_e2e_seconds", ttft + mean_decode);
        }
        // transport analogs: the hop-cost model is what the live router
        // and frame codec measure (place = one hop, steal/RTT = two)
        let hop = cfg.transport_hop_s.max(0.0);
        metrics::observe("areal_route_place_seconds", hop);
        if stolen_requests > 0 {
            metrics::observe("areal_route_steal_seconds", 2.0 * hop);
        }
        metrics::observe("areal_frame_rtt_seconds", 2.0 * hop);
        // admission + failure counters, live-name parity
        metrics::inc("areal_sched_admitted_total", submitted);
        metrics::inc("areal_socket_reconnects_total", failed_replicas);
    }
    SimReport {
        policy: "async",
        total_s: now,
        steps: steps_done,
        tokens_trained,
        effective_tps: tokens_trained / now,
        train_active_s,
        batches_per_s: steps_done as f64 / train_active_s.max(1e-12),
        effective_tps_active: tokens_trained / train_active_s.max(1e-12),
        gen_tokens,
        gen_util: busy / gen_dev_seconds.max(1e-12),
        interrupts,
        mean_staleness: stats::mean(&staleness_samples),
        max_staleness: max_stale,
        prefill_tokens,
        cached_prefill_tokens,
        recompute_tokens,
        cache_hit_rate: if prompt_total > 0.0 {
            cached_prefill_tokens / prompt_total
        } else {
            0.0
        },
        route_policy: cfg.route_policy.name(),
        stolen_requests,
        failed_replicas,
        requeued_requests,
        transport_hops,
        gen_to_train,
        train_to_gen,
        timeline,
    }
}

/// Run the policy named by `mode` ("sync" | "overlap" | "async").
pub fn run_policy(mode: &str, cfg: &SimConfig) -> SimReport {
    match mode {
        "sync" => run_sync(cfg),
        "overlap" => run_overlap(cfg),
        "async" => run_async(cfg),
        other => panic!("unknown sim policy {other}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::profile::{MODEL_1_5B, MODEL_7B};

    fn small_cfg(model: crate::sim::profile::ModelProfile) -> SimConfig {
        // steady-state regime: enough steps that the initial inflight surge
        // (the pre-gate warmup the paper also excludes) washes out
        let mut c = SimConfig::paper_default(model, 64, 16384.0);
        c.n_steps = 12;
        c
    }

    #[test]
    fn streamed_weights_track_broadcast_and_charge_chunks() {
        // at loopback-grade hops the streamed plan must be competitive
        // with the tree broadcast (the transfer itself costs the same;
        // only where it lands differs), and the chunk accounting must
        // flow to the same counter the live WeightStreamer uses
        crate::util::metrics::set_enabled(true);
        let mut cfg = small_cfg(MODEL_1_5B);
        let broadcast = run_async(&cfg);
        cfg.weight_stream = true;
        cfg.transport_hop_s = 1e-4;
        let before = crate::util::metrics::snapshot()
            .counter("areal_weight_chunks_total")
            .unwrap_or(0);
        let streamed = run_async(&cfg);
        let after = crate::util::metrics::snapshot()
            .counter("areal_weight_chunks_total")
            .unwrap_or(0);
        assert!(after > before, "streamed adoptions must account chunks");
        assert!(
            streamed.effective_tps > 0.9 * broadcast.effective_tps,
            "streamed {} vs broadcast {}",
            streamed.effective_tps,
            broadcast.effective_tps
        );
        // WAN-grade hops make per-chunk round-trips dominate: the sweep
        // has a crossover, streaming is not uniformly better
        cfg.transport_hop_s = 10.0;
        let dear = run_async(&cfg);
        assert!(dear.effective_tps < streamed.effective_tps);
    }

    #[test]
    fn async_beats_sync_throughput() {
        let cfg = small_cfg(MODEL_1_5B);
        let sync = run_sync(&cfg);
        let asy = run_async(&cfg);
        assert!(
            asy.effective_tps > 1.3 * sync.effective_tps,
            "async {} vs sync {}",
            asy.effective_tps,
            sync.effective_tps
        );
    }

    #[test]
    fn async_beats_overlap() {
        let cfg = small_cfg(MODEL_7B);
        let ovl = run_overlap(&cfg);
        let asy = run_async(&cfg);
        assert!(asy.effective_tps > ovl.effective_tps,
                "async {} vs overlap {}", asy.effective_tps, ovl.effective_tps);
    }

    #[test]
    fn eta_zero_is_fully_on_policy() {
        // η=0 degenerates to synchronous RL (paper §5.1): every consumed
        // sample was generated by the current policy version
        let mut cfg = small_cfg(MODEL_1_5B);
        cfg.eta = Some(0);
        cfg.n_steps = 4;
        let r = run_async(&cfg);
        assert_eq!(r.max_staleness, 0);
        assert_eq!(r.mean_staleness, 0.0);
    }

    #[test]
    fn staleness_grows_with_eta() {
        // Eq. 3 gates *submission* lag; consumption staleness of stragglers
        // can exceed η (the paper mitigates via oldest-first priority), but
        // it must grow with η and η=1 must stay close to 1 on average
        let mut cfg = small_cfg(MODEL_1_5B);
        cfg.eta = Some(1);
        let tight = run_async(&cfg);
        cfg.eta = Some(16);
        let loose = run_async(&cfg);
        assert!(tight.mean_staleness < loose.mean_staleness,
                "{} vs {}", tight.mean_staleness, loose.mean_staleness);
        assert!(tight.mean_staleness <= 2.0, "{}", tight.mean_staleness);
    }

    #[test]
    fn throughput_grows_with_eta_then_saturates() {
        // the Fig-5c / Table-7 shape: η=0 is slow, moderate η much faster,
        // large η adds little more
        let mut cfg = small_cfg(MODEL_1_5B);
        cfg.n_steps = 8;
        cfg.eta = Some(0);
        let e0 = run_async(&cfg).effective_tps;
        cfg.eta = Some(4);
        let e4 = run_async(&cfg).effective_tps;
        cfg.eta = Some(16);
        let e16 = run_async(&cfg).effective_tps;
        assert!(e4 > 1.2 * e0, "eta=4 {e4} should beat eta=0 {e0}");
        assert!(e16 < 1.5 * e4, "eta=16 {e16} should saturate vs eta=4 {e4}");
    }

    #[test]
    fn sync_devices_idle_on_stragglers() {
        // Fig 1: synchronous generation leaves straggler bubbles — devices
        // that finish early wait for the longest output in the batch
        let cfg = small_cfg(MODEL_1_5B);
        let sync = run_sync(&cfg);
        assert!(
            sync.gen_util < 0.85,
            "sync gen util {} should show idle bubbles",
            sync.gen_util
        );
    }

    #[test]
    fn interruptible_beats_draining() {
        // Fig 6b regime: 4 nodes, generation throughput (the paper's
        // metric) — draining for weight sync starves the decode batch
        let mut cfg = SimConfig::paper_default(MODEL_7B, 32, 16384.0);
        cfg.n_steps = 10;
        let with = run_async(&cfg);
        cfg.interruptible = false;
        let without = run_async(&cfg);
        let gen_with = with.gen_tokens / with.total_s;
        let gen_without = without.gen_tokens / without.total_s;
        assert!(
            gen_with > gen_without,
            "interruptible gen tps {gen_with} vs drain {gen_without}"
        );
        assert!(with.interrupts > 0);
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = small_cfg(MODEL_1_5B);
        let a = run_async(&cfg);
        let b = run_async(&cfg);
        assert_eq!(a.total_s, b.total_s);
        assert_eq!(a.tokens_trained, b.tokens_trained);
        assert_eq!(a.cache_hit_rate, b.cache_hit_rate);
    }

    #[test]
    fn prefix_cache_reduces_prompt_prefill() {
        // serve/'s radix cache in the cost model: G-sibling groups share
        // the prompt prefill, so the cached run computes far fewer prompt
        // tokens and is at least as fast
        let mut cfg = small_cfg(MODEL_1_5B);
        let with = run_async(&cfg);
        cfg.prefix_cache = false;
        let without = run_async(&cfg);
        assert_eq!(without.cache_hit_rate, 0.0);
        assert_eq!(without.cached_prefill_tokens, 0.0);
        assert!(
            with.cache_hit_rate > 0.5,
            "G={} groups should mostly hit: {}",
            cfg.group_size,
            with.cache_hit_rate
        );
        assert!(
            with.prefill_tokens < 0.5 * without.prefill_tokens,
            "cached prefill {} vs uncached {}",
            with.prefill_tokens,
            without.prefill_tokens
        );
        assert!(
            with.effective_tps > 0.99 * without.effective_tps,
            "cache must not slow the system: {} vs {}",
            with.effective_tps,
            without.effective_tps
        );
    }

    #[test]
    fn affinity_routing_beats_fifo_across_replicas() {
        // the W-replica policy sweep: with W >= 2 replicas and G >= 4
        // siblings per group, affinity routing computes strictly fewer
        // prompt-prefill tokens (higher aggregate hit rate) than the
        // scattered fifo baseline, at no throughput cost
        let mut cfg = small_cfg(MODEL_1_5B); // 48 gen replicas, G=16
        cfg.route_policy = RoutePolicy::Affinity;
        let aff = run_async(&cfg);
        cfg.route_policy = RoutePolicy::Fifo;
        let fifo = run_async(&cfg);
        assert_eq!(aff.route_policy, "affinity");
        assert_eq!(fifo.route_policy, "fifo");
        assert!(
            aff.prefill_tokens < fifo.prefill_tokens,
            "affinity computed {} !< fifo computed {}",
            aff.prefill_tokens,
            fifo.prefill_tokens
        );
        assert!(
            aff.cache_hit_rate > fifo.cache_hit_rate,
            "affinity hit {} !> fifo hit {}",
            aff.cache_hit_rate,
            fifo.cache_hit_rate
        );
        // scattering G=16 siblings over 48 replicas leaves fifo nearly
        // uncached while affinity stays close to (G-1)/G
        assert!(fifo.cache_hit_rate < 0.2, "fifo hit {}", fifo.cache_hit_rate);
        assert!(aff.cache_hit_rate > 0.5, "affinity hit {}", aff.cache_hit_rate);
        assert!(
            aff.effective_tps >= 0.99 * fifo.effective_tps,
            "affinity must not cost throughput: {} vs {}",
            aff.effective_tps,
            fifo.effective_tps
        );
    }

    #[test]
    fn probe_routing_beats_affinity_under_families_and_steals() {
        // the ISSUE-3 acceptance bar at cluster scale: prompts fall into
        // families sharing half their tokens, each replica's pool retains
        // one family prefix, and dry replicas steal once the gate blocks.
        // Probe placement (measured family warmth − load penalty)
        // specializes replicas by family; family-blind affinity
        // interleaves families on every replica and thrashes the resident
        // prefix — strictly more prompt prefill computed.
        let mut cfg = small_cfg(MODEL_1_5B);
        cfg.n_steps = 16;
        cfg.n_prompt_families = 4;
        cfg.family_prefix_frac = 0.5;
        cfg.route_steal_max = 2;
        cfg.route_policy = RoutePolicy::Probe;
        let probe = run_async(&cfg);
        cfg.route_policy = RoutePolicy::Affinity;
        let aff = run_async(&cfg);
        assert_eq!(probe.route_policy, "probe");
        assert!(
            probe.prefill_tokens < aff.prefill_tokens,
            "probe computed {} !< affinity {}",
            probe.prefill_tokens,
            aff.prefill_tokens
        );
        assert!(
            probe.cache_hit_rate > aff.cache_hit_rate,
            "probe hit {} !> affinity {}",
            probe.cache_hit_rate,
            aff.cache_hit_rate
        );
        assert!(
            probe.effective_tps >= 0.99 * aff.effective_tps,
            "probe must not cost throughput: {} vs {}",
            probe.effective_tps,
            aff.effective_tps
        );
    }

    #[test]
    fn replica_failure_requeues_without_loss() {
        // membership sweep: a generation replica dies mid-run under both
        // placement policies; its queued and in-flight requests requeue
        // onto the survivors, the run still completes every PPO step, and
        // the accounting stays conservative (nothing trained that was
        // never generated)
        for policy in [RoutePolicy::Affinity, RoutePolicy::Probe] {
            let mut cfg = small_cfg(MODEL_1_5B);
            cfg.n_steps = 6;
            cfg.route_policy = policy;
            cfg.route_steal_max = 2;
            cfg.fail_replica = Some((0, 2));
            let r = run_async(&cfg);
            assert_eq!(r.steps, cfg.n_steps, "{}: run must survive the loss", policy.name());
            assert_eq!(r.failed_replicas, 1);
            assert!(
                r.requeued_requests > 0,
                "{}: the lost replica held work to requeue",
                policy.name()
            );
            assert!(r.tokens_trained <= r.gen_tokens + 1e-6);
            // and the baseline without failure is unperturbed
            cfg.fail_replica = None;
            let clean = run_async(&cfg);
            assert_eq!(clean.failed_replicas, 0);
            assert_eq!(clean.requeued_requests, 0);
        }
    }

    #[test]
    fn transport_hop_latency_predicts_remote_replica_cost() {
        // ISSUE-4 tentpole, sim leg: model per-hop submit/pull latency so
        // the sim predicts when remote replicas stop paying off. Loopback
        // (socket-transport) hops are within noise of the in-process
        // model; WAN-grade hops serialize every refill behind a
        // round-trip and throughput collapses.
        let mut cfg = small_cfg(MODEL_1_5B);
        let local = run_async(&cfg);
        assert_eq!(local.transport_hops, 0, "hop accounting off at hop=0");
        cfg.transport_hop_s = 1e-4; // ~100us loopback socket
        let cheap = run_async(&cfg);
        assert!(cheap.transport_hops > 0);
        cfg.transport_hop_s = 60.0; // remote replicas far past paying off
        let dear = run_async(&cfg);
        assert!(
            cheap.effective_tps >= 0.95 * local.effective_tps,
            "loopback hops must be ~free: {} vs {}",
            cheap.effective_tps,
            local.effective_tps
        );
        assert!(
            dear.effective_tps < cheap.effective_tps,
            "hop cost must be monotone: {} !< {}",
            dear.effective_tps,
            cheap.effective_tps
        );
        assert!(
            dear.effective_tps < 0.9 * local.effective_tps,
            "60s hops must visibly hurt: {} vs {}",
            dear.effective_tps,
            local.effective_tps
        );
        assert!(dear.total_s > local.total_s);
    }

    /// The ISSUE-5 drift workload — see
    /// [`SimConfig::drift_rebalance_workload`] (one constructor shared
    /// with `bench_sim`, so the bench's `rebalance_drift` baseline always
    /// matches the tested scenario). The short phase carries most of the
    /// steps: every static split is badly wrong in at least one phase.
    fn drift_cfg(frac: f64, rebalance: bool) -> SimConfig {
        SimConfig::drift_rebalance_workload(frac, rebalance)
    }

    #[test]
    fn dynamic_rebalance_beats_static_fractions_on_drift() {
        // the ISSUE-5 acceptance sweep: on a workload whose output-length
        // distribution drifts mid-run, the staleness-headroom rebalancer
        // must match-or-beat EVERY static gen_fraction on simulated
        // effective throughput — a static split is tuned for one phase
        // and pays for it in the other; the dynamic policy re-splits at
        // the drift
        let mut best_static = f64::NEG_INFINITY;
        let mut best_frac = 0.0;
        for frac in [0.5, 0.625, 0.75, 0.875] {
            let r = run_async(&drift_cfg(frac, false));
            assert_eq!(r.steps, 32, "static {frac} must complete");
            assert_eq!(r.gen_to_train + r.train_to_gen, 0, "static fleet moved");
            if r.effective_tps > best_static {
                best_static = r.effective_tps;
                best_frac = frac;
            }
        }
        let dynamic = run_async(&drift_cfg(0.75, true));
        assert_eq!(dynamic.steps, 32, "dynamic run must complete");
        assert!(
            dynamic.effective_tps >= 0.999 * best_static,
            "dynamic {:.0} tps must be >= best static {:.0} tps (frac {best_frac})",
            dynamic.effective_tps,
            best_static
        );
        // and it must have actually rebalanced, both directions: grown
        // past the startup split in the generation-bound long phase,
        // shed capacity back to training in the short phase
        assert!(dynamic.train_to_gen > 0, "no train->gen conversion happened");
        assert!(dynamic.gen_to_train > 0, "no gen->train conversion happened");
        // conservation still holds across every conversion
        assert!(dynamic.tokens_trained <= dynamic.gen_tokens + 1e-6);
    }

    #[test]
    fn train_pool_doubling_scales_batch_rate() {
        // elastic-DP acceptance (DESIGN.md §11): on the same drift
        // workload, doubling the training pool (gen_fraction 0.875 → 0.75
        // on 64 GPUs is 8 → 16 train GPUs) must raise trained batches per
        // active-train second by ≥ 1.5× — compute scales with the pool
        // while the fixed allreduce floor keeps the speedup sub-linear.
        // This is the modeled twin of what a gen→train conversion buys
        // once converted workers serve grad_step shards.
        let small = run_async(&drift_cfg(0.875, false));
        let big = run_async(&drift_cfg(0.75, false));
        assert_eq!(small.steps, 32, "small-pool run must complete");
        assert_eq!(big.steps, 32, "big-pool run must complete");
        let ratio = big.batches_per_s / small.batches_per_s;
        assert!(
            ratio >= 1.5,
            "2x train pool must give >=1.5x batch rate, got {ratio:.2} \
             ({:.3} -> {:.3} batches/s)",
            small.batches_per_s,
            big.batches_per_s
        );
        // token-normalized, the speedup stays roughly sub-linear: the
        // allreduce floor does not shrink with the pool (small slack — the
        // two runs' trained-token mixes differ by a few percent)
        let tps_ratio = big.effective_tps_active / small.effective_tps_active;
        assert!(
            tps_ratio < 2.2,
            "active-tps scaling should stay near-linear at most, got {tps_ratio:.2}"
        );
        // active time is a subset of wall time (same token numerator)
        assert!(big.effective_tps_active >= big.effective_tps);
        assert!(small.train_active_s > big.train_active_s);
    }

    #[test]
    fn rebalanced_run_is_deterministic_and_conservative() {
        let mut cfg = drift_cfg(0.75, true);
        cfg.n_steps = 10;
        cfg.len_drift = Some((0.4, 0.02));
        let a = run_async(&cfg);
        let b = run_async(&cfg);
        assert_eq!(a.total_s, b.total_s);
        assert_eq!(a.tokens_trained, b.tokens_trained);
        assert_eq!(a.gen_to_train, b.gen_to_train);
        assert_eq!(a.train_to_gen, b.train_to_gen);
        assert!(a.gen_util > 0.0 && a.gen_util <= 1.0 + 1e-9, "{}", a.gen_util);
    }

    #[test]
    fn weight_updates_invalidate_sim_cache() {
        // version-tagged cache entries die on update_weights: the hit rate
        // stays strictly below the ideal (G-1)/G of an uninterrupted stream
        let cfg = small_cfg(MODEL_1_5B);
        let r = run_async(&cfg);
        let ideal = (cfg.group_size - 1) as f64 / cfg.group_size as f64;
        assert!(r.cache_hit_rate > 0.0);
        assert!(
            r.cache_hit_rate < ideal,
            "hit rate {} should lose some hits to weight-update invalidation \
             (ideal {ideal})",
            r.cache_hit_rate
        );
        // interrupts force committed-context recompute, never cached
        assert!(r.recompute_tokens > 0.0);
    }

    #[test]
    fn conservation_tokens_trained_le_generated() {
        let cfg = small_cfg(MODEL_1_5B);
        let r = run_async(&cfg);
        assert!(r.tokens_trained <= r.gen_tokens + 1e-6);
        assert_eq!(r.steps, cfg.n_steps);
    }
}
