//! Workload model: output-length distributions for the simulator.
//!
//! LRM outputs are heavy-tailed — the paper's Fig. 1 idle time comes from
//! the gap between the mean and the longest output in a batch. We use a
//! truncated lognormal, parameterized by (mean_target, sigma), capped at
//! the context budget, matching the qualitative shape of R1-style output
//! length histograms.

use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct LenSampler {
    mu: f64,
    sigma: f64,
    pub min_len: f64,
    pub max_len: f64,
}

impl LenSampler {
    /// Target mean (before truncation) and log-space sigma; lengths are
    /// clamped to [min_len, max_len].
    pub fn new(mean: f64, sigma: f64, min_len: f64, max_len: f64) -> Self {
        assert!(mean > 0.0 && sigma >= 0.0 && max_len >= min_len);
        // mean of lognormal = exp(mu + sigma^2/2)
        let mu = mean.ln() - sigma * sigma / 2.0;
        LenSampler { mu, sigma, min_len, max_len }
    }

    /// The paper's evaluation contexts: 16k/32k total with 1k prompts.
    /// Mean generation ≈ ctx/4, matching long-CoT training regimes.
    pub fn for_context(ctx: f64) -> Self {
        let max_gen = ctx - 1024.0;
        LenSampler::new(max_gen / 4.0, 0.9, 64.0, max_gen)
    }

    /// The same distribution with the (pre-truncation) mean scaled by
    /// `k` — the sim's mid-run output-length drift: a lognormal's mean is
    /// `exp(mu + sigma²/2)`, so scaling the mean by `k` is a `ln k` shift
    /// of `mu` with the spread and the truncation window unchanged.
    pub fn scale_mean(&self, k: f64) -> LenSampler {
        assert!(k > 0.0, "mean scale must be positive");
        LenSampler {
            mu: self.mu + k.ln(),
            sigma: self.sigma,
            min_len: self.min_len,
            max_len: self.max_len,
        }
    }

    pub fn sample(&self, rng: &mut Rng) -> f64 {
        rng.lognormal(self.mu, self.sigma)
            .clamp(self.min_len, self.max_len)
    }

    pub fn sample_n(&self, rng: &mut Rng, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.sample(rng)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    #[test]
    fn mean_is_close_to_target() {
        let s = LenSampler::new(2000.0, 0.5, 1.0, 1e9);
        let mut rng = Rng::new(1);
        let xs = s.sample_n(&mut rng, 20_000);
        let m = stats::mean(&xs);
        assert!((m - 2000.0).abs() / 2000.0 < 0.05, "{m}");
    }

    #[test]
    fn truncation_respected() {
        let s = LenSampler::for_context(16384.0);
        let mut rng = Rng::new(2);
        for x in s.sample_n(&mut rng, 5000) {
            assert!((64.0..=15360.0).contains(&x), "{x}");
        }
    }

    #[test]
    fn scale_mean_shifts_the_distribution() {
        let s = LenSampler::new(2000.0, 0.5, 1.0, 1e9);
        let quarter = s.scale_mean(0.25);
        let mut rng = Rng::new(7);
        let m = stats::mean(&quarter.sample_n(&mut rng, 20_000));
        assert!((m - 500.0).abs() / 500.0 < 0.05, "{m}");
        // clamps are preserved, not rescaled
        let capped = LenSampler::new(100.0, 0.5, 64.0, 256.0).scale_mean(100.0);
        let mut rng = Rng::new(8);
        for x in capped.sample_n(&mut rng, 2000) {
            assert!((64.0..=256.0).contains(&x), "{x}");
        }
    }

    #[test]
    fn heavy_tail_exists() {
        // p95 should be much larger than the median — the source of the
        // paper's synchronous-idle problem
        let s = LenSampler::for_context(32768.0);
        let mut rng = Rng::new(3);
        let xs = s.sample_n(&mut rng, 20_000);
        let p50 = stats::percentile(&xs, 50.0);
        let p95 = stats::percentile(&xs, 95.0);
        assert!(p95 > 2.5 * p50, "p50={p50} p95={p95}");
    }
}
