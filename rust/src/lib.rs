//! AReaL: a fully asynchronous RL training system for language reasoning.
//!
//! Three-layer reproduction of Fu et al., "AReaL: A Large-Scale Asynchronous
//! Reinforcement Learning System for Language Reasoning" (2025):
//! Rust coordinator (this crate) + AOT-compiled JAX model + Pallas kernels.
//! See DESIGN.md for the system inventory and experiment index.

pub mod algo;
pub mod config;
pub mod coordinator;
pub mod interp;
pub mod lint;
pub mod reward;
pub mod runtime;
pub mod serve;
pub mod exp;
pub mod sim;
pub mod tasks;
pub mod text;
pub mod util;
