//! Advantage estimation per the paper's §B.1 PPO configuration:
//!
//! - no critic / reference model; γ = λ = 1 and the reward is terminal-only,
//!   so every response token carries the same sequence-level advantage;
//! - baseline: group mean over the n responses sampled per prompt
//!   (GRPO-style, critic disabled) or leave-one-out (RLOO, Appendix C.4);
//! - advantage normalization across the global batch (§B.1).

use std::collections::HashMap;

use crate::util::stats;

/// Which per-group baseline to subtract.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Baseline {
    /// advantage = r − mean(group), the paper's default (critic disabled)
    GroupMean,
    /// leave-one-out: advantage_i = r_i − mean(group \ {i})
    Rloo,
    /// no baseline (ablation)
    None,
}

#[derive(Debug, Clone)]
pub struct AdvantageEstimator {
    pub baseline: Baseline,
    /// normalize advantages over the global batch (paper §B.1: true)
    pub normalize: bool,
}

impl Default for AdvantageEstimator {
    fn default() -> Self {
        AdvantageEstimator { baseline: Baseline::GroupMean, normalize: true }
    }
}

impl AdvantageEstimator {
    /// Compute per-sequence advantages from (group id, terminal reward)
    /// pairs. Order is preserved.
    pub fn advantages(&self, rewards: &[(u64, f32)]) -> Vec<f32> {
        // group sums/counts
        let mut sums: HashMap<u64, (f64, usize)> = HashMap::new();
        for &(g, r) in rewards {
            let e = sums.entry(g).or_insert((0.0, 0));
            e.0 += r as f64;
            e.1 += 1;
        }
        let mut adv: Vec<f64> = rewards
            .iter()
            .map(|&(g, r)| {
                let (sum, n) = sums[&g];
                match self.baseline {
                    Baseline::None => r as f64,
                    Baseline::GroupMean => r as f64 - sum / n as f64,
                    Baseline::Rloo => {
                        if n <= 1 {
                            // leave-one-out undefined for singleton groups;
                            // fall back to no baseline
                            r as f64
                        } else {
                            r as f64 - (sum - r as f64) / (n - 1) as f64
                        }
                    }
                }
            })
            .collect();
        if self.normalize {
            stats::normalize(&mut adv);
        }
        adv.into_iter().map(|a| a as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    fn est(b: Baseline, norm: bool) -> AdvantageEstimator {
        AdvantageEstimator { baseline: b, normalize: norm }
    }

    #[test]
    fn group_mean_zero_sums_per_group() {
        let rewards = vec![(0, 5.0), (0, -5.0), (0, 5.0), (1, -5.0), (1, -5.0)];
        let adv = est(Baseline::GroupMean, false).advantages(&rewards);
        let g0: f32 = adv[..3].iter().sum();
        let g1: f32 = adv[3..].iter().sum();
        assert!(g0.abs() < 1e-5);
        assert!(g1.abs() < 1e-5);
        // all-wrong group: zero advantage (no gradient signal), the GRPO
        // degenerate case
        assert!(adv[3].abs() < 1e-5 && adv[4].abs() < 1e-5);
    }

    #[test]
    fn rloo_matches_closed_form() {
        let rewards = vec![(7, 5.0), (7, -5.0), (7, 5.0), (7, 5.0)];
        let adv = est(Baseline::Rloo, false).advantages(&rewards);
        // r0=5; others mean = (−5+5+5)/3 = 5/3
        assert!((adv[0] - (5.0 - 5.0 / 3.0)).abs() < 1e-5);
        // r1=−5; others mean = 5
        assert!((adv[1] - (-5.0 - 5.0)).abs() < 1e-5);
    }

    #[test]
    fn rloo_singleton_group_falls_back() {
        let adv = est(Baseline::Rloo, false).advantages(&[(1, 5.0)]);
        assert_eq!(adv, vec![5.0]);
    }

    #[test]
    fn normalization_gives_unit_scale() {
        let rewards: Vec<(u64, f32)> =
            (0..16).map(|i| (i / 4, if i % 3 == 0 { 5.0 } else { -5.0 })).collect();
        let adv = est(Baseline::GroupMean, true).advantages(&rewards);
        let v: Vec<f64> = adv.iter().map(|&a| a as f64).collect();
        assert!(stats::mean(&v).abs() < 1e-6);
        assert!((stats::std(&v) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn prop_group_mean_invariant_to_reward_shift_after_norm() {
        // shifting all rewards by a constant leaves normalized group-mean
        // advantages unchanged
        prop_check(50, |rng| {
            let n_groups = rng.range_usize(2, 5);
            let per = rng.range_usize(2, 6);
            let mut rewards = Vec::new();
            for g in 0..n_groups as u64 {
                for _ in 0..per {
                    rewards.push((g, if rng.chance(0.5) { 5.0 } else { -5.0 }));
                }
            }
            // degenerate all-equal batches normalize to zeros; skip those
            let base = est(Baseline::GroupMean, true).advantages(&rewards);
            let shifted: Vec<(u64, f32)> =
                rewards.iter().map(|&(g, r)| (g, r + 3.0)).collect();
            let shifted_adv = est(Baseline::GroupMean, true).advantages(&shifted);
            for (a, b) in base.iter().zip(&shifted_adv) {
                crate::prop_assert!((a - b).abs() < 1e-4, "shift changed adv");
            }
            Ok(())
        });
    }

    #[test]
    fn prop_order_preserved() {
        prop_check(50, |rng| {
            let n = rng.range_usize(1, 20);
            let rewards: Vec<(u64, f32)> = (0..n)
                .map(|i| (i as u64 % 3, rng.range_i64(-5, 5) as f32))
                .collect();
            let adv = est(Baseline::GroupMean, false).advantages(&rewards);
            crate::prop_assert!(adv.len() == n, "length changed");
            Ok(())
        });
    }
}
