//! RL algorithm pieces computed on the Rust side: advantage estimation and
//! training-batch assembly. The loss itself lives in the AOT `train_step`
//! artifact (decoupled PPO, Eq. 5); everything that shapes its inputs —
//! rewards → advantages → normalization → minibatch tensors — lives here.

pub mod advantage;

pub use advantage::{AdvantageEstimator, Baseline};
