//! Experiment drivers — one per paper table/figure (DESIGN.md §8). Each
//! driver prints the paper-style rows and writes CSVs under `runs/exp/`.

pub mod common;
pub mod figures;
pub mod tables;

use anyhow::{bail, Result};

/// Dispatch `areal exp <id> [key=value...]`.
pub fn run(id: &str, overrides: &[String]) -> Result<()> {
    match id {
        "fig1" => figures::fig1(),
        "fig3" => figures::fig3(overrides),
        "fig4" => figures::fig4(overrides),
        "fig5" => figures::fig5(overrides),
        "fig6a" => figures::fig6a(overrides),
        "fig6b" => figures::fig6b(overrides),
        "table1" => tables::table1(overrides),
        "table2" => tables::table2(overrides),
        "table45" => tables::table45(overrides),
        "table6" => tables::table6(overrides),
        "table7" => tables::table7(overrides),
        "table8" => tables::table8(overrides),
        other => bail!(
            "unknown experiment '{other}'; available: fig1 fig3 fig4 fig5 \
             fig6a fig6b table1 table2 table45 table6 table7 table8"
        ),
    }
}
