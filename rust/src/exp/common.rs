//! Shared experiment plumbing: real-system run helper, markdown table
//! printing, CSV output directory.

use std::path::PathBuf;

use anyhow::Result;

use crate::config::Config;
use crate::coordinator::{RunReport, System};

pub fn out_dir() -> PathBuf {
    let d = PathBuf::from("runs/exp");
    let _ = std::fs::create_dir_all(&d);
    d
}

/// Parse simple `key=value` overrides used by the drivers themselves
/// (returns the value for `key` if present).
pub fn arg(overrides: &[String], key: &str) -> Option<String> {
    overrides.iter().find_map(|o| {
        o.split_once('=')
            .filter(|(k, _)| *k == key)
            .map(|(_, v)| v.to_string())
    })
}

pub fn arg_usize(overrides: &[String], key: &str, default: usize) -> usize {
    arg(overrides, key)
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Build + run the real system: defaults → user overrides (unknown keys are
/// driver-specific and skipped) → driver mutation `f`.
pub fn run_real(extra: &[String], f: impl FnOnce(&mut Config)) -> Result<RunReport> {
    let mut cfg = Config::default();
    for o in extra {
        if let Some((k, v)) = o.split_once('=') {
            let _ = cfg.set(k.trim(), v.trim()); // unknown keys: driver args
        }
    }
    f(&mut cfg);
    cfg.validate()?;
    let sys = System::build(cfg)?;
    sys.run()
}

/// Print a markdown table.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n### {title}\n");
    println!("| {} |", header.join(" | "));
    println!("|{}|", header.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
    for r in rows {
        println!("| {} |", r.join(" | "));
    }
    println!();
}

pub fn fmt(v: f64, prec: usize) -> String {
    format!("{v:.prec$}")
}
