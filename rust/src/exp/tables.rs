//! Table drivers (Table 1, 2, 4/5, 6, 7, 8).

use anyhow::Result;

use crate::config::{BaselineCfg, Mode};
use crate::coordinator::evalgen;
use crate::sim::{self, SimConfig};
use crate::tasks::evalsuite;
use crate::util::logging::CsvWriter;

use super::common::{arg, arg_usize, fmt, out_dir, print_table, run_real};

/// Table 1 — end-to-end comparison. Two parts:
/// (a) simulated training hours at the paper's scale (1.5B..32B, H800);
/// (b) real wall-clock sync vs async on this testbed (same steps, same
///     budget) with final eval — the accuracy-parity claim.
pub fn table1(overrides: &[String]) -> Result<()> {
    // (a) simulated hours at paper scale
    let mut rows = Vec::new();
    for (m, nodes, steps) in [
        (sim::profile::MODEL_1_5B, 16usize, 250usize),
        (sim::profile::MODEL_7B, 24, 250),
        (sim::profile::MODEL_14B, 32, 80),
        (sim::profile::MODEL_32B, 48, 80),
    ] {
        let gpus = nodes * 8;
        let mut c = SimConfig::paper_default(m, gpus, 32768.0);
        c.n_steps = 6; // simulate a window, extrapolate per-step cost
        let sync = sim::run_sync(&c);
        let asy = sim::run_async(&c);
        let sync_h = sync.total_s / c.n_steps as f64 * steps as f64 / 3600.0;
        let asy_h = asy.total_s / c.n_steps as f64 * steps as f64 / 3600.0;
        rows.push(vec![
            m.name.to_string(),
            format!("{nodes}"),
            format!("{steps}"),
            fmt(sync_h, 1),
            fmt(asy_h, 1),
            format!("{:.2}x", sync_h / asy_h),
        ]);
    }
    print_table(
        "Table 1 (sim, paper scale) — training hours",
        &["model", "nodes", "PPO steps", "sync hours", "AReaL hours", "speedup"],
        &rows,
    );

    // (b) real runs on this testbed
    let steps = arg_usize(overrides, "steps", 8);
    let tier = arg(overrides, "tier").unwrap_or_else(|| "nano".into());
    let mut rows = Vec::new();
    for mode in [Mode::Sync, Mode::Overlap, Mode::Async] {
        let report = run_real(overrides, |cfg| {
            cfg.tier = tier.clone();
            cfg.task = arg(overrides, "task").unwrap_or_else(|| "sort".into());
            cfg.mode = mode;
            cfg.max_staleness = Some(4);
            cfg.ppo_steps = steps;
            cfg.sft_steps = arg_usize(overrides, "sft_steps", 20);
            cfg.group_size = 4;
            cfg.global_batch = 16;
            cfg.ppo_minibatches = 2;
            cfg.n_rollout_workers = 1;
            cfg.eval_samples = 0;
            cfg.lr = 5e-4;
        })?;
        let k = report.steps.len().saturating_sub(3);
        let final_correct = report.steps[k..]
            .iter()
            .map(|m| m.correct_frac)
            .sum::<f64>()
            / (report.steps.len() - k).max(1) as f64;
        rows.push(vec![
            mode.name().into(),
            format!("{steps}"),
            fmt(report.wall_s, 1),
            fmt(final_correct, 3),
            fmt(report.effective_tps, 0),
        ]);
    }
    print_table(
        &format!("Table 1 (real, tier {tier}) — wall clock for {steps} PPO steps"),
        &["system", "PPO steps", "wall s", "final correct", "eff. tok/s"],
        &rows,
    );
    Ok(())
}

/// Shared machinery for Table 2 / 7 / 8: staleness sweep with a chosen
/// objective/baseline, real runs, eval on held-out suites.
fn staleness_sweep(overrides: &[String], decoupled: bool, baseline: BaselineCfg,
                   title: &str) -> Result<()> {
    let steps = arg_usize(overrides, "steps", 12);
    let tier = arg(overrides, "tier").unwrap_or_else(|| "nano".into());
    let task = arg(overrides, "task").unwrap_or_else(|| "sort".into());
    let etas: Vec<Option<u64>> = arg(overrides, "etas")
        .map(|s| {
            s.split(',')
                .map(|x| if x == "inf" { None } else { Some(x.parse().unwrap()) })
                .collect()
        })
        .unwrap_or_else(|| vec![Some(0), Some(1), Some(4), None]);
    let mut rows = Vec::new();
    let mut w = CsvWriter::create(
        out_dir().join(format!("{}.csv", title.replace(' ', "_"))),
        &["eta", "final_correct", "tps", "wall_s", "mean_staleness"],
    )?;
    for &eta in &etas {
        let report = run_real(overrides, |cfg| {
            cfg.tier = tier.clone();
            cfg.task = task.clone();
            cfg.mode = Mode::Async;
            cfg.max_staleness = eta;
            cfg.decoupled = decoupled;
            cfg.baseline = baseline;
            cfg.ppo_steps = steps;
            cfg.sft_steps = arg_usize(overrides, "sft_steps", 20);
            cfg.group_size = 4;
            cfg.global_batch = 16;
            cfg.ppo_minibatches = 2;
            cfg.n_rollout_workers = 1;
            cfg.eval_samples = 0;
            cfg.lr = 5e-4;
        })?;
        let k = report.steps.len().saturating_sub(3);
        let final_correct = report.steps[k..]
            .iter()
            .map(|m| m.correct_frac)
            .sum::<f64>()
            / (report.steps.len() - k).max(1) as f64;
        let mean_stale = report.steps.iter().map(|m| m.mean_staleness).sum::<f64>()
            / report.steps.len().max(1) as f64;
        let eta_s = eta.map_or("inf".to_string(), |e| e.to_string());
        w.row_mixed(&eta_s, &[final_correct, report.effective_tps, report.wall_s,
                              mean_stale])?;
        rows.push(vec![
            eta_s,
            fmt(final_correct, 3),
            fmt(report.effective_tps, 0),
            fmt(report.wall_s, 1),
            fmt(mean_stale, 2),
        ]);
    }
    w.flush()?;
    print_table(
        title,
        &["max staleness η", "final correct", "eff. tok/s", "wall s",
          "mean staleness"],
        &rows,
    );
    Ok(())
}

/// Table 2 — staleness × objective: runs BOTH naive and decoupled sweeps.
pub fn table2(overrides: &[String]) -> Result<()> {
    staleness_sweep(overrides, false, BaselineCfg::GroupMean,
                    "Table 2 — naive PPO (w/o decoupled objective)")?;
    staleness_sweep(overrides, true, BaselineCfg::GroupMean,
                    "Table 2 — decoupled PPO objective (Eq. 5)")
}

/// Table 7 — small-scale staleness-throughput trade-off (PPO).
pub fn table7(overrides: &[String]) -> Result<()> {
    staleness_sweep(overrides, true, BaselineCfg::GroupMean,
                    "Table 7 — staleness vs throughput (PPO, small scale)")
}

/// Table 8 — RLOO advantage variant.
pub fn table8(overrides: &[String]) -> Result<()> {
    staleness_sweep(overrides, true, BaselineCfg::Rloo,
                    "Table 8 — staleness vs throughput (RLOO)")
}

/// Tables 4/5 — additional benchmarks: train one model per task family and
/// evaluate on every held-out suite.
pub fn table45(overrides: &[String]) -> Result<()> {
    let steps = arg_usize(overrides, "steps", 12);
    for task in ["math", "code"] {
        let report = run_real(overrides, |cfg| {
            cfg.tier = arg(overrides, "tier").unwrap_or_else(|| "tiny".into());
            cfg.task = task.into();
            cfg.level_lo = 1;
            cfg.level_hi = 2;
            cfg.ppo_steps = steps;
            cfg.sft_steps = arg_usize(overrides, "sft_steps", 60);
            cfg.group_size = 4;
            cfg.global_batch = 16;
            cfg.ppo_minibatches = 2;
            cfg.n_rollout_workers = 1;
            cfg.eval_samples = 1;
            cfg.lr = 5e-4;
        })?;
        let rows: Vec<Vec<String>> = report
            .eval
            .iter()
            .map(|r| {
                vec![
                    r.suite.to_string(),
                    fmt(r.pass_at_1, 3),
                    format!("{}", r.n_prompts),
                    fmt(r.mean_completion_len, 1),
                ]
            })
            .collect();
        print_table(
            &format!("Table 4/5 — held-out suites after RL ({task})"),
            &["suite", "pass@1", "prompts", "mean completion len"],
            &rows,
        );
    }
    Ok(())
}

/// Table 6 — architecture generalization: llama-style variant (RMSNorm,
/// SiLU-gated MLP, tied embeddings).
pub fn table6(overrides: &[String]) -> Result<()> {
    let steps = arg_usize(overrides, "steps", 10);
    let mut rows = Vec::new();
    for (label, tier) in [("gpt (small)", "small"), ("llama (llama_small)", "llama_small")] {
        let report = run_real(overrides, |cfg| {
            cfg.tier = tier.into();
            cfg.task = "sort".into();
            cfg.level_lo = 2;
            cfg.level_hi = 4;
            cfg.ppo_steps = steps;
            cfg.sft_steps = arg_usize(overrides, "sft_steps", 30);
            cfg.group_size = 4;
            cfg.global_batch = 16;
            cfg.ppo_minibatches = 2;
            cfg.n_rollout_workers = 1;
            cfg.eval_samples = 0;
            cfg.lr = 5e-4;
        })?;
        let k = report.steps.len().saturating_sub(3);
        let fc = report.steps[k..].iter().map(|m| m.correct_frac).sum::<f64>()
            / (report.steps.len() - k).max(1) as f64;
        rows.push(vec![label.into(), fmt(fc, 3), fmt(report.effective_tps, 0)]);
    }
    print_table(
        "Table 6 — architecture generalization (async RL works on both)",
        &["architecture", "final correct", "eff. tok/s"],
        &rows,
    );
    Ok(())
}

/// Utility used by the CLI `eval` command.
pub fn eval_checkpoint(tier: &str, task: &str, ckpt: &std::path::Path,
                       artifacts: &std::path::Path, samples: usize) -> Result<()> {
    let manifest = crate::runtime::Manifest::load(artifacts)?;
    let spec = manifest.tier(tier)?;
    let names = spec.config.generation_entrypoints();
    let refs: Vec<&str> = names.iter().map(String::as_str).collect();
    let engine =
        std::sync::Arc::new(crate::runtime::Engine::load_subset(spec, Some(&refs))?);
    let state = crate::runtime::params::load_checkpoint(ckpt, spec)?;
    let mut rows = Vec::new();
    for suite in evalsuite::suites_for(task) {
        let r = evalgen::eval_suite(&engine, &state.params, &suite, samples, 0.0, 1)?;
        rows.push(vec![r.suite.to_string(), fmt(r.pass_at_1, 3),
                       format!("{}", r.n_prompts)]);
    }
    print_table(&format!("eval: {tier}/{task} @ {ckpt:?}"),
                &["suite", "pass@1", "prompts"], &rows);
    Ok(())
}
