//! Figure drivers (Fig 1, 3, 4, 5, 6a, 6b).

use anyhow::Result;

use crate::config::Mode;
use crate::coordinator::batching::{dynamic_allocate, padded_cost, standard_allocate};
use crate::sim::{self, SimConfig};
use crate::util::logging::CsvWriter;
use crate::util::rng::Rng;

use super::common::{arg, arg_usize, fmt, out_dir, print_table, run_real};

/// Fig 1 — execution timelines: synchronous vs one-step overlap, showing
/// inference-device idling (simulated at paper scale).
pub fn fig1() -> Result<()> {
    let cfg = SimConfig::paper_default(sim::profile::MODEL_7B, 64, 16384.0);
    let mut c = cfg.clone();
    c.n_steps = 2;
    let sync = sim::run_sync(&c);
    let ovl = sim::run_overlap(&c);
    println!("== Fig 1 (left): synchronous RL system ==");
    print!("{}", sim::timeline::render(&sync.timeline, 72));
    println!("gen-device utilization: {:.0}%", 100.0 * sync.gen_util);
    println!("\n== Fig 1 (right): one-step overlap ==");
    print!("{}", sim::timeline::render(&ovl.timeline, 72));
    println!("gen-device utilization: {:.0}%", 100.0 * ovl.gen_util);
    std::fs::write(out_dir().join("fig1_sync.csv"),
                   sim::timeline::to_csv(&sync.timeline))?;
    std::fs::write(out_dir().join("fig1_overlap.csv"),
                   sim::timeline::to_csv(&ovl.timeline))?;
    Ok(())
}

/// Fig 3 — AReaL generation management: interruptions (✕) at weight
/// arrivals. Simulated at scale + a real trace from the in-process system.
pub fn fig3(overrides: &[String]) -> Result<()> {
    let mut c = SimConfig::paper_default(sim::profile::MODEL_7B, 64, 16384.0);
    c.n_steps = 3;
    let asy = sim::run_async(&c);
    println!("== Fig 3: AReaL asynchronous generation management (sim) ==");
    print!("{}", sim::timeline::render(&asy.timeline, 72));
    println!(
        "gen util {:.0}%  interrupts {}  mean staleness {:.2}",
        100.0 * asy.gen_util, asy.interrupts, asy.mean_staleness
    );

    // real trace (nano tier, a few steps)
    let steps = arg_usize(overrides, "steps", 3);
    let report = run_real(overrides, |cfg| {
        cfg.tier = arg(overrides, "tier").unwrap_or_else(|| "nano".into());
        cfg.task = "sort".into();
        cfg.group_size = 4;
        cfg.global_batch = 8;
        cfg.ppo_minibatches = 2;
        cfg.ppo_steps = steps;
        cfg.n_rollout_workers = 1;
        cfg.sft_steps = 2;
        cfg.eval_samples = 0;
        cfg.max_staleness = Some(4);
    })?;
    let csv = report.trace.to_csv();
    std::fs::write(out_dir().join("fig3_real_trace.csv"), &csv)?;
    let interrupts = report
        .trace
        .count(|e| matches!(e, crate::coordinator::Event::Interrupt { .. }));
    println!(
        "\nreal trace ({} steps): {} events, {} in-flight interruptions, \
         interrupted-trajectory fraction per step: {:?}",
        steps,
        csv.lines().count() - 1,
        interrupts,
        report
            .steps
            .iter()
            .map(|m| (m.interrupted_frac * 100.0).round() / 100.0)
            .collect::<Vec<_>>()
    );
    println!("wrote {:?}", out_dir().join("fig3_real_trace.csv"));
    // the live run populated the telemetry registry (TTFT/e2e spans, gate
    // and scheduler gauges) — print the end-of-run rollup alongside the
    // trace so the figure's latency numbers are reproducible at a glance
    print!(
        "{}",
        crate::util::metrics::render_summary(&crate::util::metrics::snapshot())
    );
    Ok(())
}

/// Fig 4 — strong scaling: effective throughput vs device count, AReaL vs
/// synchronous (verl-like), ctx 16k and 32k, all four model sizes.
pub fn fig4(overrides: &[String]) -> Result<()> {
    let models = [
        sim::profile::MODEL_1_5B,
        sim::profile::MODEL_7B,
        sim::profile::MODEL_14B,
        sim::profile::MODEL_32B,
    ];
    let device_counts: Vec<usize> = arg(overrides, "gpus")
        .map(|s| s.split(',').filter_map(|x| x.parse().ok()).collect())
        .unwrap_or_else(|| vec![64, 128, 256, 512]);
    let mut w = CsvWriter::create(
        out_dir().join("fig4.csv"),
        &["model_ctx_gpus", "sync_tps", "async_tps", "speedup", "ideal_async"],
    )?;
    for ctx in [16384.0, 32768.0] {
        let mut rows = Vec::new();
        for m in &models {
            let mut base_async = 0.0;
            for (i, &g) in device_counts.iter().enumerate() {
                let mut c = SimConfig::paper_default(*m, g, ctx);
                c.n_steps = 6;
                let sync = sim::run_sync(&c);
                let asy = sim::run_async(&c);
                if i == 0 {
                    base_async = asy.effective_tps / g as f64;
                }
                let ideal = base_async * g as f64;
                rows.push(vec![
                    m.name.to_string(),
                    format!("{g}"),
                    fmt(sync.effective_tps / 1e3, 1),
                    fmt(asy.effective_tps / 1e3, 1),
                    fmt(asy.effective_tps / sync.effective_tps, 2),
                    fmt(ideal / 1e3, 1),
                ]);
                w.row_mixed(
                    &format!("{},{},{}", m.name, ctx as usize, g),
                    &[sync.effective_tps, asy.effective_tps,
                      asy.effective_tps / sync.effective_tps, ideal],
                )?;
            }
        }
        print_table(
            &format!("Fig 4 — strong scaling, ctx {} (effective ktok/s)", ctx as usize),
            &["model", "gpus", "sync(verl-like)", "AReaL", "speedup", "ideal-linear"],
            &rows,
        );
    }
    w.flush()?;
    println!("wrote {:?}", out_dir().join("fig4.csv"));
    Ok(())
}

/// Fig 5 — ablation learning curves: naive vs decoupled PPO across η
/// (real runs, reduced scale), plus effective throughput (5c).
pub fn fig5(overrides: &[String]) -> Result<()> {
    let steps = arg_usize(overrides, "steps", 12);
    let etas: Vec<Option<u64>> = arg(overrides, "etas")
        .map(|s| {
            s.split(',')
                .map(|x| if x == "inf" { None } else { Some(x.parse().unwrap()) })
                .collect()
        })
        .unwrap_or_else(|| vec![Some(0), Some(1), Some(4)]);
    let mut rows = Vec::new();
    let mut w = CsvWriter::create(
        out_dir().join("fig5_curves.csv"),
        &["decoupled", "eta", "step", "reward", "correct", "kl", "tps"],
    )?;
    for decoupled in [false, true] {
        for &eta in &etas {
            let report = run_real(overrides, |cfg| {
                cfg.tier = arg(overrides, "tier").unwrap_or_else(|| "nano".into());
                cfg.task = arg(overrides, "task").unwrap_or_else(|| "sort".into());
                cfg.mode = Mode::Async;
                cfg.max_staleness = eta;
                cfg.decoupled = decoupled;
                cfg.ppo_steps = steps;
                cfg.sft_steps = arg_usize(overrides, "sft_steps", 30);
                cfg.group_size = 4;
                cfg.global_batch = 16;
                cfg.ppo_minibatches = 2;
                cfg.n_rollout_workers = 1;
                cfg.eval_samples = 0;
                cfg.lr = 5e-4;
            })?;
            for m in &report.steps {
                w.row_mixed(
                    &format!("{},{}", decoupled as u8,
                             eta.map_or("inf".into(), |e| e.to_string())),
                    &[m.step as f64, m.reward_mean, m.correct_frac, m.approx_kl,
                      m.effective_tps],
                )?;
            }
            let k = report.steps.len().saturating_sub(4);
            let last = &report.steps[k..];
            let final_correct = last.iter().map(|m| m.correct_frac).sum::<f64>()
                / last.len().max(1) as f64;
            rows.push(vec![
                if decoupled { "decoupled (Eq.5)" } else { "naive PPO" }.into(),
                eta.map_or("inf".into(), |e| e.to_string()),
                fmt(final_correct, 3),
                fmt(report.effective_tps, 0),
                fmt(report.wall_s, 1),
            ]);
        }
    }
    w.flush()?;
    print_table(
        "Fig 5 — objective × staleness (final correctness, reduced scale)",
        &["objective", "η", "final correct", "eff. tok/s", "wall s"],
        &rows,
    );
    println!("curves: {:?}", out_dir().join("fig5_curves.csv"));
    Ok(())
}

/// Fig 6a — dynamic micro-batch allocation vs standard batching:
/// analytic padded-cost + real train-phase wall-clock.
pub fn fig6a(overrides: &[String]) -> Result<()> {
    // analytic sweep over workload mixes (executable-cost model)
    let mut rows = Vec::new();
    let mut rng = Rng::new(7);
    for (name, short_frac) in [("early (short seqs)", 1.0), ("mixed", 0.6), ("late (long seqs)", 0.2)] {
        let t = 256usize;
        let lens: Vec<usize> = (0..64)
            .map(|_| {
                if rng.chance(short_frac) {
                    rng.range_usize(16, t / 2)
                } else {
                    rng.range_usize(t / 2 + 1, t - 1)
                }
            })
            .collect();
        let dyn_b = dynamic_allocate(&lens, 4 * t, 4, 16);
        let std_b = standard_allocate(&lens, 4, 16);
        let dyn_cost = padded_cost(&dyn_b, &[t / 2, t], 16);
        let std_cost = padded_cost(&std_b, &[t], 16);
        rows.push(vec![
            name.into(),
            format!("{}", std_b.len()),
            format!("{}", dyn_b.len()),
            format!("{std_cost}"),
            format!("{dyn_cost}"),
            fmt(std_cost as f64 / dyn_cost as f64, 2),
        ]);
    }
    print_table(
        "Fig 6a — Algorithm-1 dynamic batching (analytic executable cost)",
        &["workload", "std µbatches", "dyn µbatches", "std cost", "dyn cost",
          "speedup"],
        &rows,
    );

    // real measurement: identical short-completion workloads through both
    // policies (nano tier)
    let steps = arg_usize(overrides, "steps", 3);
    let mut real_rows = Vec::new();
    for dynamic in [false, true] {
        let report = run_real(overrides, |cfg| {
            cfg.tier = arg(overrides, "tier").unwrap_or_else(|| "nano".into());
            cfg.task = "sort".into();
            cfg.dynamic_batching = dynamic;
            cfg.token_budget = 256;
            cfg.ppo_steps = steps;
            cfg.sft_steps = 0;
            cfg.group_size = 4;
            cfg.global_batch = 16;
            cfg.ppo_minibatches = 2;
            cfg.n_rollout_workers = 1;
            cfg.eval_samples = 0;
        })?;
        let train_wall: f64 = report.steps.iter().map(|m| m.wall_s).sum();
        let tokens: usize = report.steps.iter().map(|m| m.tokens_consumed).sum();
        real_rows.push(vec![
            if dynamic { "dynamic (Alg.1)" } else { "standard" }.into(),
            fmt(train_wall, 2),
            format!("{tokens}"),
            fmt(tokens as f64 / train_wall, 0),
        ]);
    }
    print_table(
        "Fig 6a — real train-phase throughput (nano tier)",
        &["policy", "train wall s", "tokens", "train tok/s"],
        &real_rows,
    );
    Ok(())
}

/// Fig 6b — interruptible generation ablation (sim at 4-node scale, like
/// the paper, plus the real coordinator counters).
pub fn fig6b(_overrides: &[String]) -> Result<()> {
    let mut rows = Vec::new();
    for m in [sim::profile::MODEL_1_5B, sim::profile::MODEL_7B] {
        let mut c = SimConfig::paper_default(m, 32, 16384.0); // 4 nodes
        c.n_steps = 10;
        let with = sim::run_async(&c);
        c.interruptible = false;
        let without = sim::run_async(&c);
        rows.push(vec![
            m.name.to_string(),
            fmt(without.gen_tokens / without.total_s / 1e3, 1),
            fmt(with.gen_tokens / with.total_s / 1e3, 1),
            format!("+{:.0}%",
                    100.0 * (with.gen_tokens / with.total_s
                             / (without.gen_tokens / without.total_s) - 1.0)),
        ]);
    }
    print_table(
        "Fig 6b — interruptible generation, 4 nodes (gen ktok/s)",
        &["model", "w/o interruption", "w/ interruption", "gain"],
        &rows,
    );
    println!("(paper reports +12% for 1.5B and +17% for 7B on 4 nodes)");
    Ok(())
}
