//! Lock-order discipline over the coordinator/ and serve/ planes.
//!
//! Finds every point where a lock guard is still live when another lock is
//! acquired — in the same function, or one call deep through an
//! unambiguously-named callee — and checks the resulting edge against the
//! canonical DAG declared in `lint/lock_order.txt`. Also flags guards held
//! across blocking operations (`send(`, `write_all(`, `flush(`, zero-arg
//! `.join()`).
//!
//! Heuristics (documented limits, not bugs):
//! - A lock acquisition is a zero-arg `.lock()/.read()/.write()` or the
//!   poison-recovering `.plock()/.pread()/.pwrite()` from `util::sync`.
//! - The lock's name is the last field identifier in the receiver chain
//!   (`self.convert.plock()` → `convert`); a bare `self.lock()` uses the
//!   file stem.
//! - Let-bound guards live to the end of their block (or `drop(var)`);
//!   expression temporaries live to the end of their statement.
//! - Callee propagation is one level deep and only through function names
//!   defined exactly once in the scanned tree, excluding names that
//!   collide with std-library methods (`len`, `get`, `count`, ...).

use std::collections::{BTreeMap, BTreeSet, HashMap, HashSet};
use std::path::Path;

use super::lexer::{allowed, Kind};
use super::{Finding, SourceFile};

const ACQ: &[&str] = &["lock", "plock", "read", "pread", "write", "pwrite"];
const BLOCKING: &[&str] = &["send", "write_all", "flush"];
/// Names that collide with std-library methods: never propagated through,
/// because a call site cannot be attributed to the repo's own definition.
const STD_DENY: &[&str] = &[
    "len", "is_empty", "count", "get", "push", "pop", "insert", "remove", "clone", "take",
    "clear", "contains", "drain", "iter", "next", "send", "write", "read", "lock", "flush",
    "join",
];

fn is_acq(name: &str) -> bool {
    ACQ.contains(&name)
}

fn zero_arg_call(f: &SourceFile, i: usize) -> bool {
    i + 2 < f.toks.len() && f.toks[i + 1].text == "(" && f.toks[i + 2].text == ")"
}

/// Walk the `.`-chain left of the acquisition method: the lock name is the
/// innermost field before the method, or the file stem for bare `self`.
fn receiver_name(f: &SourceFile, i: usize) -> Option<String> {
    let mut names: Vec<String> = Vec::new();
    let mut j = i as isize - 1;
    while j >= 1 && f.toks[j as usize].text == "." {
        let k = (j - 1) as usize;
        if f.toks[k].kind == Kind::Ident {
            names.push(f.toks[k].text.clone());
            j = k as isize - 1;
        } else {
            break;
        }
    }
    if names.is_empty() {
        return None;
    }
    for nm in &names {
        if nm != "self" {
            return Some(nm.clone());
        }
    }
    Some(f.stem.clone())
}

/// A function body: token span `[open_brace, close_brace]` within its file.
struct FnSpan {
    file: usize,
    open: usize,
    close: usize,
}

struct Guard {
    lock: String,
    var: Option<String>,
    depth: usize,
    temp: bool,
}

pub struct LockReport {
    pub findings: Vec<Finding>,
    /// observed edge -> first witnessing site `(file, line, via)`
    pub edges: BTreeMap<(String, String), (String, usize, String)>,
}

/// Locks acquired directly by each fn name, for callee propagation.
type FnLocks = HashMap<String, BTreeSet<String>>;

/// Scan fn definitions: spans, per-name definition counts, and the set of
/// locks each (uniquely named) fn acquires directly.
fn pass1(files: &[SourceFile]) -> (Vec<FnSpan>, HashMap<String, usize>, FnLocks) {
    let mut spans: Vec<FnSpan> = Vec::new();
    let mut defs: HashMap<String, usize> = HashMap::new();
    let mut locks: FnLocks = HashMap::new();
    for (fi, f) in files.iter().enumerate() {
        let n = f.toks.len();
        let mut i = 0usize;
        while i < n {
            if f.toks[i].text == "fn" && i + 1 < n && f.toks[i + 1].kind == Kind::Ident {
                let name = f.toks[i + 1].text.clone();
                // find the body's opening brace; a `;` first means a trait decl
                let mut j = i + 2;
                let mut open: Option<usize> = None;
                while j < n {
                    if f.toks[j].text == "{" {
                        open = Some(j);
                        break;
                    }
                    if f.toks[j].text == ";" {
                        break;
                    }
                    j += 1;
                }
                let open = match open {
                    Some(o) => o,
                    None => {
                        i += 2;
                        continue;
                    }
                };
                let mut d = 0isize;
                let mut k = open;
                while k < n {
                    if f.toks[k].text == "{" {
                        d += 1;
                    } else if f.toks[k].text == "}" {
                        d -= 1;
                        if d == 0 {
                            break;
                        }
                    }
                    k += 1;
                }
                let close = k.min(n - 1);
                *defs.entry(name.clone()).or_insert(0) += 1;
                for q in open..close.min(n) {
                    if f.toks[q].kind == Kind::Ident
                        && is_acq(&f.toks[q].text)
                        && q > 0
                        && f.toks[q - 1].text == "."
                        && zero_arg_call(f, q)
                    {
                        if let Some(nm) = receiver_name(f, q) {
                            locks.entry(name.clone()).or_default().insert(nm);
                        }
                    }
                }
                spans.push(FnSpan { file: fi, open, close });
                i = close;
            }
            i += 1;
        }
    }
    (spans, defs, locks)
}

/// Walk each function body tracking live guards; record lock→lock edges
/// (direct and one-callee-deep) and guards held across blocking ops.
pub fn analyze(files: &[SourceFile]) -> LockReport {
    let (spans, defs, fn_locks) = pass1(files);
    let mut findings: Vec<Finding> = Vec::new();
    let mut edges: BTreeMap<(String, String), (String, usize, String)> = BTreeMap::new();
    let mut note_edge = |a: &str, b: &str, rel: &str, line: usize, via: &str| {
        edges
            .entry((a.to_string(), b.to_string()))
            .or_insert((rel.to_string(), line, via.to_string()));
    };
    for span in &spans {
        let f = &files[span.file];
        let n = f.toks.len();
        let mut guards: Vec<Guard> = Vec::new();
        let mut depth = 0usize;
        let mut q = span.open;
        while q <= span.close && q < n {
            let t = &f.toks[q];
            if t.text == "{" {
                depth += 1;
            } else if t.text == "}" {
                guards.retain(|g| g.depth < depth);
                depth = depth.saturating_sub(1);
            } else if t.text == ";" {
                guards.retain(|g| !g.temp);
            } else if t.kind == Kind::Ident
                && t.text == "drop"
                && q + 2 < n
                && f.toks[q + 1].text == "("
                && f.toks[q + 2].kind == Kind::Ident
            {
                let v = f.toks[q + 2].text.clone();
                guards.retain(|g| g.var.as_deref() != Some(v.as_str()));
            } else if t.kind == Kind::Ident
                && is_acq(&t.text)
                && q > 0
                && f.toks[q - 1].text == "."
                && zero_arg_call(f, q)
            {
                if let Some(nm) = receiver_name(f, q) {
                    if !allowed(&f.allows, "lock-order", t.line) {
                        for g in &guards {
                            if g.lock != nm {
                                note_edge(&g.lock, &nm, &f.rel, t.line, "direct");
                            }
                        }
                    }
                    // let-bound iff `let [mut] v = <chain>.acq();` exactly
                    let mut var: Option<String> = None;
                    let mut b = q as isize - 1;
                    while b >= span.open as isize
                        && !matches!(f.toks[b as usize].text.as_str(), ";" | "{" | "}")
                    {
                        b -= 1;
                    }
                    let s = (b + 1) as usize;
                    if s < n && f.toks[s].text == "let" {
                        let mut vi = s + 1;
                        if vi < n && f.toks[vi].text == "mut" {
                            vi += 1;
                        }
                        if vi < n && f.toks[vi].kind == Kind::Ident {
                            var = Some(f.toks[vi].text.clone());
                        }
                    }
                    let stmt_ends_here = q + 3 < n && f.toks[q + 3].text == ";";
                    let temp = !(var.is_some() && stmt_ends_here);
                    guards.push(Guard {
                        lock: nm,
                        var: if temp { None } else { var },
                        depth,
                        temp,
                    });
                }
            } else if t.kind == Kind::Ident
                && BLOCKING.contains(&t.text.as_str())
                && q + 1 < n
                && f.toks[q + 1].text == "("
            {
                if let Some(g) = guards.last() {
                    if !allowed(&f.allows, "lock-order", t.line) {
                        findings.push(Finding::new(
                            "lock-order",
                            &f.rel,
                            t.line,
                            format!("`{}(` called while guard of `{}` is live", t.text, g.lock),
                        ));
                    }
                }
            } else if t.kind == Kind::Ident
                && t.text == "join"
                && q > 0
                && f.toks[q - 1].text == "."
                && zero_arg_call(f, q)
            {
                if let Some(g) = guards.last() {
                    if !allowed(&f.allows, "lock-order", t.line) {
                        findings.push(Finding::new(
                            "lock-order",
                            &f.rel,
                            t.line,
                            format!("`.join()` called while guard of `{}` is live", g.lock),
                        ));
                    }
                }
            }
            // one-level callee propagation through unambiguous names
            if t.kind == Kind::Ident
                && !is_acq(&t.text)
                && !STD_DENY.contains(&t.text.as_str())
                && defs.get(&t.text).copied() == Some(1)
                && q + 1 < n
                && f.toks[q + 1].text == "("
                && (q == 0 || f.toks[q - 1].text != "fn")
            {
                if let Some(callee_locks) = fn_locks.get(&t.text) {
                    if !allowed(&f.allows, "lock-order", t.line) {
                        for g in &guards {
                            for cl in callee_locks {
                                if *cl != g.lock {
                                    note_edge(&g.lock, cl, &f.rel, t.line, &t.text);
                                }
                            }
                        }
                    }
                }
            }
            q += 1;
        }
    }
    LockReport { findings, edges }
}

/// The declared canonical order: `A -> B` lines from lint/lock_order.txt.
pub struct Manifest {
    pub edges: Vec<(String, String)>,
    pub nodes: BTreeSet<String>,
}

pub fn parse_manifest(text: &str) -> Manifest {
    let mut edges = Vec::new();
    let mut nodes = BTreeSet::new();
    for raw in text.lines() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let parts: Vec<&str> = line.split("->").collect();
        if parts.len() == 2 {
            let a = parts[0].trim().to_string();
            let b = parts[1].trim().to_string();
            nodes.insert(a.clone());
            nodes.insert(b.clone());
            edges.push((a, b));
        }
    }
    Manifest { edges, nodes }
}

/// Transitive closure: every node reachable from `from` in the declared DAG.
fn reachable(m: &Manifest, from: &str) -> HashSet<String> {
    let mut seen: HashSet<String> = HashSet::new();
    let mut stack: Vec<String> = vec![from.to_string()];
    while let Some(cur) = stack.pop() {
        for (a, b) in &m.edges {
            if *a == cur && !seen.contains(b) {
                seen.insert(b.clone());
                stack.push(b.clone());
            }
        }
    }
    seen
}

fn has_cycle(m: &Manifest) -> Option<String> {
    for node in &m.nodes {
        if reachable(m, node).contains(node) {
            return Some(node.clone());
        }
    }
    None
}

/// Full lock-order rule: analyze the scanned plane, then check every
/// observed edge against the manifest's transitive closure.
pub fn check(root: &Path, files: &[SourceFile]) -> Vec<Finding> {
    let rep = analyze(files);
    let mut findings = rep.findings;
    let manifest_rel = "lint/lock_order.txt";
    let text = std::fs::read_to_string(root.join(manifest_rel)).unwrap_or_default();
    let manifest = parse_manifest(&text);
    if let Some(node) = has_cycle(&manifest) {
        findings.push(Finding::new(
            "lock-order",
            manifest_rel,
            1,
            format!("declared lock order contains a cycle through `{node}`"),
        ));
    }
    for ((a, b), (rel, line, via)) in &rep.edges {
        if text.is_empty() {
            findings.push(Finding::new(
                "lock-order",
                rel,
                *line,
                format!("`{b}` acquired under guard of `{a}` but {manifest_rel} is missing"),
            ));
            continue;
        }
        let ok = manifest.nodes.contains(a)
            && manifest.nodes.contains(b)
            && reachable(&manifest, a).contains(b);
        if !ok {
            let how = if via == "direct" {
                String::new()
            } else {
                format!(" (via `{via}()`)")
            };
            findings.push(Finding::new(
                "lock-order",
                rel,
                *line,
                format!("`{b}` acquired while guard of `{a}` is live{how} — edge not declared in {manifest_rel}"),
            ));
        }
    }
    findings
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::source_from_str;

    #[test]
    fn nested_acquisition_yields_edge() {
        let src = "fn f(&self) { let g = self.alpha.plock(); let h = self.beta.plock(); }";
        let files = vec![source_from_str("x/a.rs", src)];
        let rep = analyze(&files);
        assert!(rep
            .edges
            .contains_key(&("alpha".to_string(), "beta".to_string())));
    }

    #[test]
    fn scoped_guards_yield_no_edge() {
        let src = "fn f(&self) { { let g = self.alpha.plock(); } let h = self.beta.plock(); }";
        let files = vec![source_from_str("x/a.rs", src)];
        let rep = analyze(&files);
        assert!(rep.edges.is_empty());
    }

    #[test]
    fn drop_releases_guard() {
        let src = "fn f(&self) { let g = self.alpha.plock(); drop(g); let h = self.beta.plock(); }";
        let files = vec![source_from_str("x/a.rs", src)];
        let rep = analyze(&files);
        assert!(rep.edges.is_empty());
    }

    #[test]
    fn temporary_guard_dies_at_statement_end() {
        let src = "fn f(&self) { let v = self.alpha.plock().len(); let h = self.beta.plock(); }";
        let files = vec![source_from_str("x/a.rs", src)];
        let rep = analyze(&files);
        assert!(rep.edges.is_empty());
    }

    #[test]
    fn callee_propagation_one_level() {
        let src = "fn inner(&self) { let g = self.beta.plock(); }\n\
                   fn outer(&self) { let g = self.alpha.plock(); self.inner(); }";
        let files = vec![source_from_str("x/a.rs", src)];
        let rep = analyze(&files);
        let key = ("alpha".to_string(), "beta".to_string());
        assert!(rep.edges.contains_key(&key));
        assert_eq!(rep.edges[&key].2, "inner");
    }

    #[test]
    fn blocking_op_under_guard_flagged() {
        let src = "fn f(&self) { let g = self.alpha.plock(); tx.send(1); }";
        let files = vec![source_from_str("x/a.rs", src)];
        let rep = analyze(&files);
        assert_eq!(rep.findings.len(), 1);
        assert_eq!(rep.findings[0].rule, "lock-order");
    }

    #[test]
    fn manifest_closure_accepts_transitive_edges() {
        let m = parse_manifest("# comment\na -> b\nb -> c\n");
        assert!(reachable(&m, "a").contains("c"));
        assert!(has_cycle(&m).is_none());
        let cyc = parse_manifest("a -> b\nb -> a\n");
        assert!(has_cycle(&cyc).is_some());
    }
}
