//! areal-lint: project-invariant static analysis for the concurrent
//! rollout/train planes (DESIGN.md §12).
//!
//! Four rule families, each with an inline escape hatch
//! `// areal-lint: allow(<rule>, reason="...")`:
//!
//! - `lock-order` — lock acquired while another guard is live must follow
//!   the canonical DAG in `lint/lock_order.txt`; guards must not be held
//!   across channel sends / socket writes / thread joins.
//! - `panic` / `index` — no unannotated `.unwrap()` / `.expect(` /
//!   `panic!` / unchecked slice index in non-test serve/ + coordinator/.
//! - `event-csv` / `metric-doc` / `metric-sim` / `config-doc` — drift
//!   exhaustiveness between code and its restatements (trace CSV arms and
//!   decode tests, the DESIGN.md metric inventory, the simulator's metric
//!   emissions, docs/CONFIG.md).
//! - `epoch-fence` — replica teardown calls must flow an epoch argument,
//!   and `reopen()` epochs must not be discarded.
//!
//! Run with `cargo run --release --bin areal_lint` from the repo root.

pub mod drift;
pub mod lexer;
pub mod lock_graph;
pub mod report;
pub mod rules;

use std::path::{Path, PathBuf};

pub use report::{render, Finding};

/// One lexed source file with its test region already removed.
pub struct SourceFile {
    /// path relative to the lint root, with `/` separators
    pub rel: String,
    /// file stem, used as the lock name for bare `self.lock()`
    pub stem: String,
    pub toks: Vec<lexer::Tok>,
    pub allows: lexer::Allows,
}

pub fn source_from_str(rel: &str, src: &str) -> SourceFile {
    let lx = lexer::lex(src);
    let cut = lexer::test_cut(&lx.toks);
    let stem = Path::new(rel)
        .file_stem()
        .and_then(|s| s.to_str())
        .unwrap_or("file")
        .to_string();
    SourceFile { rel: rel.to_string(), stem, toks: lx.toks[..cut].to_vec(), allows: lx.allows }
}

fn load(root: &Path, rel: &str) -> Option<SourceFile> {
    let src = std::fs::read_to_string(root.join(rel)).ok()?;
    Some(source_from_str(rel, &src))
}

/// All `.rs` files under `root/<dir>`, recursively, sorted, as root-relative
/// `/`-separated paths.
fn rs_files(root: &Path, dir: &str) -> Vec<String> {
    let mut out: Vec<String> = Vec::new();
    let mut stack: Vec<PathBuf> = vec![root.join(dir)];
    while let Some(d) = stack.pop() {
        let entries = match std::fs::read_dir(&d) {
            Ok(e) => e,
            Err(_) => continue,
        };
        for entry in entries.flatten() {
            let p = entry.path();
            if p.is_dir() {
                stack.push(p);
            } else if p.extension().and_then(|e| e.to_str()) == Some("rs") {
                if let Ok(rel) = p.strip_prefix(root) {
                    out.push(rel.to_string_lossy().replace('\\', "/"));
                }
            }
        }
    }
    out.sort();
    out
}

/// Lint a tree laid out like this repository (rust/src/{serve,coordinator},
/// lint/lock_order.txt, DESIGN.md, docs/CONFIG.md). Fixture trees in tests
/// use the same shape; rules whose anchor files are absent do not fire.
pub fn lint_tree(root: &Path) -> Vec<Finding> {
    let mut findings: Vec<Finding> = Vec::new();

    // the concurrent plane: lock-order + panic/index scope
    let mut plane: Vec<SourceFile> = Vec::new();
    for dir in ["rust/src/serve", "rust/src/coordinator"] {
        for rel in rs_files(root, dir) {
            if let Some(sf) = load(root, &rel) {
                plane.push(sf);
            }
        }
    }
    findings.extend(lock_graph::check(root, &plane));
    findings.extend(rules::panic_index(&plane));

    // whole-crate scans: metric drift + epoch fences
    let mut all: Vec<SourceFile> = Vec::new();
    for rel in rs_files(root, "rust/src") {
        if let Some(sf) = load(root, &rel) {
            all.push(sf);
        }
    }
    findings.extend(drift::metrics(root, &all));
    findings.extend(rules::epoch_fence(&all));

    findings.extend(drift::event_csv(root));
    findings.extend(drift::config_doc(root));

    report::sort(&mut findings);
    findings
}
