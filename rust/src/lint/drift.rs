//! Drift-exhaustiveness rules: facts stated in one place must be restated
//! everywhere the project promises to restate them.
//!
//! event-csv: every `Event::` variant has an arm in `Tracer::to_csv` (no
//!   catch-all), and every kind string it emits is asserted by a decode
//!   test in the same file.
//! metric-doc: every `areal_*` metric-name literal at a metrics call site
//!   appears in the DESIGN.md §10 inventory (full or unprefixed form).
//! metric-sim: the same name is emitted by the simulator (`sim/run.rs`),
//!   so live runs and sim runs stay plottable on one dashboard.
//! config-doc: every `Config::KEYS` entry is documented in docs/CONFIG.md.

use std::path::Path;

use super::lexer::{allowed, lex, test_cut, Kind};
use super::{Finding, SourceFile};

const METRIC_API: &[&str] = &["inc", "set", "observe", "counter", "gauge", "histogram"];

/// event-csv rule: runs on `rust/src/coordinator/trace.rs` under `root`.
pub fn event_csv(root: &Path) -> Vec<Finding> {
    let rel = "rust/src/coordinator/trace.rs";
    let mut out: Vec<Finding> = Vec::new();
    let src = match std::fs::read_to_string(root.join(rel)) {
        Ok(s) => s,
        Err(_) => return out, // tree without a tracer: rule does not apply
    };
    let lx = lex(&src);
    let cut = test_cut(&lx.toks);
    let body = &lx.toks[..cut];
    let tests = &lx.toks[cut..];
    let n = body.len();

    // enum Event variants: depth-1 idents right after `{` or `,`
    let mut variants: Vec<(String, usize)> = Vec::new();
    for i in 0..n.saturating_sub(2) {
        if body[i].text == "enum" && body[i + 1].text == "Event" {
            let mut d = 0isize;
            let mut k = i + 2;
            while k < n {
                if body[k].text == "{" {
                    d += 1;
                } else if body[k].text == "}" {
                    d -= 1;
                    if d == 0 {
                        break;
                    }
                } else if d == 1
                    && body[k].kind == Kind::Ident
                    && k > 0
                    && (body[k - 1].text == "{" || body[k - 1].text == ",")
                {
                    variants.push((body[k].text.clone(), body[k].line));
                }
                k += 1;
            }
            break;
        }
    }

    // to_csv body span
    let mut span: Option<(usize, usize)> = None;
    for i in 0..n.saturating_sub(1) {
        if body[i].text == "fn" && body[i + 1].text == "to_csv" {
            let mut d = 0isize;
            let mut k = i + 2;
            while k < n {
                if body[k].text == "{" {
                    d += 1;
                } else if body[k].text == "}" {
                    d -= 1;
                    if d == 0 {
                        break;
                    }
                }
                k += 1;
            }
            span = Some((i, k.min(n)));
            break;
        }
    }
    let (lo, hi) = match span {
        Some(s) => s,
        None => {
            if !variants.is_empty() {
                out.push(Finding::new(
                    "event-csv",
                    rel,
                    1,
                    "enum Event exists but no to_csv fn found".to_string(),
                ));
            }
            return out;
        }
    };
    let seg = &body[lo..hi];

    // `Event::Name` arm heads
    let mut arm_names: Vec<String> = Vec::new();
    for j in 3..seg.len() {
        if seg[j].kind == Kind::Ident
            && seg[j - 1].text == ":"
            && seg[j - 2].text == ":"
            && seg[j - 3].text == "Event"
        {
            arm_names.push(seg[j].text.clone());
        }
    }
    for (v, ln) in &variants {
        if !arm_names.iter().any(|a| a == v) {
            out.push(Finding::new(
                "event-csv",
                rel,
                *ln,
                format!("Event::{v} has no to_csv arm — traces would silently drop it"),
            ));
        }
    }

    // catch-all arm `_ =>` defeats the exhaustiveness guarantee
    for j in 0..seg.len().saturating_sub(2) {
        if seg[j].text == "_" && seg[j + 1].text == "=" && seg[j + 2].text == ">" {
            out.push(Finding::new(
                "event-csv",
                rel,
                seg[j].line,
                "catch-all `_ =>` arm in to_csv — new variants would not be flagged".to_string(),
            ));
        }
    }

    // every bare kind literal emitted must be asserted by a decode test
    let mut test_blob = String::new();
    for t in tests {
        if t.kind == Kind::Str {
            test_blob.push_str(&t.text);
            test_blob.push(' ');
        }
    }
    for t in seg {
        if t.kind == Kind::Str {
            let ks = t.text.trim_matches('"');
            if !ks.is_empty() && !ks.contains(',') && !ks.contains('{') && !test_blob.contains(ks) {
                out.push(Finding::new(
                    "event-csv",
                    rel,
                    t.line,
                    format!("kind \"{ks}\" never asserted in a decode test"),
                ));
            }
        }
    }
    out
}

/// metric-doc + metric-sim: `files` is the full rust/src scan.
pub fn metrics(root: &Path, files: &[SourceFile]) -> Vec<Finding> {
    let mut out: Vec<Finding> = Vec::new();
    let design = std::fs::read_to_string(root.join("DESIGN.md")).unwrap_or_default();
    let simsrc =
        std::fs::read_to_string(root.join("rust/src/sim/run.rs")).unwrap_or_default();
    for f in files {
        let n = f.toks.len();
        for q in 0..n {
            let t = &f.toks[q];
            if t.kind != Kind::Str || !t.text.starts_with("\"areal_") {
                continue;
            }
            let lo = q.saturating_sub(6);
            let near_api = f.toks[lo..q]
                .iter()
                .any(|x| x.kind == Kind::Ident && METRIC_API.contains(&x.text.as_str()));
            if !near_api {
                continue;
            }
            let full = t.text.trim_matches('"');
            let name = full.split('{').next().unwrap_or(full);
            let base = name.strip_prefix("areal_").unwrap_or(name);
            if !design.contains(name)
                && !design.contains(base)
                && !allowed(&f.allows, "metric-doc", t.line)
            {
                out.push(Finding::new(
                    "metric-doc",
                    &f.rel,
                    t.line,
                    format!("{name} not in the DESIGN.md §10 metric inventory"),
                ));
            }
            if f.rel != "rust/src/sim/run.rs"
                && !simsrc.contains(name)
                && !allowed(&f.allows, "metric-sim", t.line)
            {
                out.push(Finding::new(
                    "metric-sim",
                    &f.rel,
                    t.line,
                    format!("{name} never emitted by sim/run.rs — sim and live dashboards drift"),
                ));
            }
        }
    }
    out
}

/// config-doc: every key in `Config::KEYS` is documented in docs/CONFIG.md.
pub fn config_doc(root: &Path) -> Vec<Finding> {
    let mut out: Vec<Finding> = Vec::new();
    let rel = "rust/src/config.rs";
    let src = match std::fs::read_to_string(root.join(rel)) {
        Ok(s) => s,
        Err(_) => return out,
    };
    let confmd = std::fs::read_to_string(root.join("docs/CONFIG.md")).unwrap_or_default();
    let lx = lex(&src);
    let toks = &lx.toks[..test_cut(&lx.toks)];
    let n = toks.len();
    for i in 0..n {
        if toks[i].text == "KEYS" {
            // skip the const's type annotation: scan from the `=`
            let mut k = i;
            while k < n && toks[k].text != "=" {
                k += 1;
            }
            let mut d = 0isize;
            while k < n {
                if toks[k].text == "[" {
                    d += 1;
                } else if toks[k].text == "]" {
                    d -= 1;
                    if d == 0 {
                        break;
                    }
                } else if d == 1
                    && toks[k].text == "("
                    && k + 1 < n
                    && toks[k + 1].kind == Kind::Str
                {
                    let key = toks[k + 1].text.trim_matches('"').to_string();
                    let backticked = format!("`{key}`");
                    let spaced = format!("{key} ");
                    if !confmd.contains(&backticked) && !confmd.contains(&spaced) {
                        out.push(Finding::new(
                            "config-doc",
                            rel,
                            toks[k + 1].line,
                            format!("Config key {key} not documented in docs/CONFIG.md"),
                        ));
                    }
                }
                k += 1;
            }
            break;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::fs;

    fn tree(dir: &str, files: &[(&str, &str)]) -> std::path::PathBuf {
        let root = std::env::temp_dir().join(format!("areal_lint_drift_{dir}"));
        let _ = fs::remove_dir_all(&root);
        for (rel, body) in files {
            let p = root.join(rel);
            fs::create_dir_all(p.parent().unwrap()).unwrap();
            fs::write(p, body).unwrap();
        }
        root
    }

    #[test]
    fn missing_arm_and_catch_all_flagged() {
        let trace = "pub enum Event { A { t: f64 }, B { t: f64 } }\n\
                     impl T { fn to_csv(&self) -> String {\n\
                       match e { Event::A { t } => \"a_kind\".into(), _ => String::new() }\n\
                     } }\n\
                     #[cfg(test)]\nmod tests { fn d() { assert!(c.contains(\"a_kind,1\")); } }\n";
        let root = tree("ec1", &[("rust/src/coordinator/trace.rs", trace)]);
        let got = event_csv(&root);
        assert!(got.iter().any(|f| f.msg.contains("Event::B")));
        assert!(got.iter().any(|f| f.msg.contains("catch-all")));
    }

    #[test]
    fn undocumented_metric_flagged() {
        let root = tree(
            "m1",
            &[
                ("DESIGN.md", "inventory: `known_total`\n"),
                ("rust/src/sim/run.rs", "// emits areal_known_total\n"),
            ],
        );
        let f = crate::lint::source_from_str(
            "rust/src/serve/x.rs",
            "fn f() { metrics::inc(\"areal_mystery_total\", 1); metrics::inc(\"areal_known_total\", 1); }",
        );
        let got = metrics(&root, &[f]);
        assert_eq!(got.iter().filter(|f| f.rule == "metric-doc").count(), 1);
        assert!(got[0].msg.contains("areal_mystery_total"));
    }

    #[test]
    fn undocumented_config_key_flagged() {
        let cfg = "impl Config { pub const KEYS: &'static [(&'static str, &'static str)] = &[\n\
                   (\"documented_key\", \"1\"), (\"mystery_key\", \"2\")]; }\n";
        let root = tree(
            "c1",
            &[
                ("rust/src/config.rs", cfg),
                ("docs/CONFIG.md", "| `documented_key` | ... |\n"),
            ],
        );
        let got = config_doc(&root);
        assert_eq!(got.len(), 1);
        assert!(got[0].msg.contains("mystery_key"));
    }
}
