//! Panic-path and epoch-fence rules.
//!
//! panic: no unannotated `.unwrap()` / `.expect(` / `panic!` /
//!   `unreachable!` / `todo!` / `unimplemented!` in non-test serve/ and
//!   coordinator/ code.
//! index: no unannotated postfix indexing `x[i]` there either — ranges
//!   (`x[a..b]`) and integer-literal indices (`x[0]`) are exempt.
//! epoch-fence: `close_salvage_at(..)` / `remove_replica_at(..)` call
//!   sites must flow an `epoch` argument, and a `reopen()` result (the new
//!   epoch) must not be discarded.
//!
//! Escape hatch scopes for `// areal-lint: allow(<rule>, reason="...")`:
//! same line, the line above, above a `fn` (covers the body), or above an
//! `impl` (covers the whole impl block).

use super::lexer::{allowed, Kind};
use super::{Finding, SourceFile};

const PANIC_MACROS: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];

/// `(start_line, end_line, rule)` spans from fn- and impl-scope allows.
fn scoped_allows(f: &SourceFile) -> Vec<(usize, usize, String)> {
    let mut out: Vec<(usize, usize, String)> = Vec::new();
    let n = f.toks.len();
    let mut i = 0usize;
    while i < n {
        let is_fn = f.toks[i].text == "fn" && i + 1 < n && f.toks[i + 1].kind == Kind::Ident;
        let is_impl = f.toks[i].text == "impl";
        if is_fn || is_impl {
            let hdr = f.toks[i].line;
            let mut rules: Vec<String> = Vec::new();
            for probe in [hdr.saturating_sub(1), hdr] {
                if let Some(rs) = f.allows.get(&probe) {
                    for r in rs {
                        rules.push(r.clone());
                    }
                }
            }
            if !rules.is_empty() {
                let mut j = i + 1;
                while j < n && f.toks[j].text != "{" && f.toks[j].text != ";" {
                    j += 1;
                }
                if j < n && f.toks[j].text == "{" {
                    let mut d = 0isize;
                    let mut k = j;
                    while k < n {
                        if f.toks[k].text == "{" {
                            d += 1;
                        } else if f.toks[k].text == "}" {
                            d -= 1;
                            if d == 0 {
                                break;
                            }
                        }
                        k += 1;
                    }
                    let end = f.toks[k.min(n - 1)].line;
                    for r in rules {
                        out.push((hdr, end, r));
                    }
                    if is_fn {
                        i = j;
                    }
                }
            }
        }
        i += 1;
    }
    out
}

fn span_allowed(spans: &[(usize, usize, String)], rule: &str, ln: usize) -> bool {
    spans.iter().any(|(a, b, r)| *a <= ln && ln <= *b && r == rule)
}

/// panic + index rules over the serve/coordinator plane.
pub fn panic_index(files: &[SourceFile]) -> Vec<Finding> {
    let mut out: Vec<Finding> = Vec::new();
    for f in files {
        let spans = scoped_allows(f);
        let n = f.toks.len();
        let ok = |rule: &str, ln: usize| {
            allowed(&f.allows, rule, ln) || span_allowed(&spans, rule, ln)
        };
        for q in 0..n {
            let t = &f.toks[q];
            if t.kind == Kind::Ident
                && (t.text == "unwrap" || t.text == "expect")
                && q > 0
                && f.toks[q - 1].text == "."
                && q + 1 < n
                && f.toks[q + 1].text == "("
                && !ok("panic", t.line)
            {
                out.push(Finding::new(
                    "panic",
                    &f.rel,
                    t.line,
                    format!(".{}( in non-test code — convert to `?` or annotate the invariant", t.text),
                ));
            }
            if t.kind == Kind::Ident
                && PANIC_MACROS.contains(&t.text.as_str())
                && q + 1 < n
                && f.toks[q + 1].text == "!"
                && !ok("panic", t.line)
            {
                out.push(Finding::new(
                    "panic",
                    &f.rel,
                    t.line,
                    format!("{}! in non-test code", t.text),
                ));
            }
            if t.text == "[" && q > 0 {
                let p = &f.toks[q - 1];
                let postfix = p.kind == Kind::Ident || p.text == ")" || p.text == "]";
                if postfix {
                    let mut d = 0isize;
                    let mut k = q;
                    while k < n {
                        if f.toks[k].text == "[" {
                            d += 1;
                        } else if f.toks[k].text == "]" {
                            d -= 1;
                            if d == 0 {
                                break;
                            }
                        }
                        k += 1;
                    }
                    let inner: Vec<&super::lexer::Tok> = f.toks[q + 1..k.min(n)].iter().collect();
                    let txt: String = inner.iter().map(|t| t.text.as_str()).collect();
                    let is_range = txt.contains("..");
                    let is_const = inner.len() == 1 && inner[0].kind == Kind::Num;
                    if !is_range && !is_const && !inner.is_empty() && !ok("index", t.line) {
                        out.push(Finding::new(
                            "index",
                            &f.rel,
                            t.line,
                            format!("unchecked index `[{txt}]` — out-of-range panics at runtime"),
                        ));
                    }
                }
            }
        }
    }
    out
}

/// epoch-fence rule over the full rust/src tree.
pub fn epoch_fence(files: &[SourceFile]) -> Vec<Finding> {
    let mut out: Vec<Finding> = Vec::new();
    for f in files {
        let n = f.toks.len();
        for q in 0..n {
            let t = &f.toks[q];
            if t.kind == Kind::Ident
                && (t.text == "close_salvage_at" || t.text == "remove_replica_at")
            {
                if q > 0 && f.toks[q - 1].text == "fn" {
                    continue; // definition, not a call site
                }
                if q + 1 >= n || f.toks[q + 1].text != "(" {
                    continue;
                }
                let mut d = 0isize;
                let mut k = q + 1;
                let mut has_epoch = false;
                while k < n {
                    if f.toks[k].text == "(" {
                        d += 1;
                    } else if f.toks[k].text == ")" {
                        d -= 1;
                        if d == 0 {
                            break;
                        }
                    } else if f.toks[k].kind == Kind::Ident && f.toks[k].text.contains("epoch") {
                        has_epoch = true;
                    }
                    k += 1;
                }
                if !has_epoch && !allowed(&f.allows, "epoch-fence", t.line) {
                    out.push(Finding::new(
                        "epoch-fence",
                        &f.rel,
                        t.line,
                        format!(
                            "{}( call without an epoch argument — bare slot indices race with slot reuse",
                            t.text
                        ),
                    ));
                }
            }
            if t.kind == Kind::Ident
                && t.text == "reopen"
                && q > 0
                && f.toks[q - 1].text == "."
                && q + 2 < n
                && f.toks[q + 1].text == "("
                && f.toks[q + 2].text == ")"
                && q + 3 < n
                && f.toks[q + 3].text == ";"
            {
                // result (the new epoch) discarded: a statement that is just
                // `<chain>.reopen();`
                let mut b = q as isize - 1;
                while b >= 0 && !matches!(f.toks[b as usize].text.as_str(), ";" | "{" | "}") {
                    b -= 1;
                }
                let s = (b + 1) as usize;
                let plain_chain =
                    (s..q).all(|x| f.toks[x].kind == Kind::Ident || f.toks[x].text == ".");
                if s < q
                    && f.toks[s].text != "let"
                    && plain_chain
                    && !allowed(&f.allows, "epoch-fence", t.line)
                {
                    out.push(Finding::new(
                        "epoch-fence",
                        &f.rel,
                        t.line,
                        "reopen() epoch discarded — callers must fence pulls on the new epoch"
                            .to_string(),
                    ));
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::source_from_str;

    #[test]
    fn bare_unwrap_flagged_annotated_passes() {
        let bad = source_from_str("x/a.rs", "fn f() { y.unwrap(); }");
        assert_eq!(panic_index(&[bad]).len(), 1);
        let good = source_from_str(
            "x/a.rs",
            "fn f() { y.unwrap(); // areal-lint: allow(panic, reason=\"ok\")\n }",
        );
        assert!(panic_index(&[good]).is_empty());
    }

    #[test]
    fn index_rule_exempts_ranges_and_consts() {
        let src = "fn f() { let a = v[i]; let b = v[0]; let c = &v[1..3]; }";
        let got = panic_index(&[source_from_str("x/a.rs", src)]);
        assert_eq!(got.len(), 1);
        assert!(got[0].msg.contains("[i]"));
    }

    #[test]
    fn fn_scope_allow_covers_body() {
        let src = "// areal-lint: allow(index, reason=\"arena ids\")\n\
                   fn f() { let a = v[i]; let b = w[j]; }";
        assert!(panic_index(&[source_from_str("x/a.rs", src)]).is_empty());
    }

    #[test]
    fn impl_scope_allow_covers_all_fns() {
        let src = "// areal-lint: allow(index, reason=\"arena ids\")\n\
                   impl T {\n fn f(&self) { v[i]; }\n fn g(&self) { w[j]; }\n }";
        assert!(panic_index(&[source_from_str("x/a.rs", src)]).is_empty());
    }

    #[test]
    fn fence_requires_epoch_argument() {
        let bad = source_from_str("x/a.rs", "fn f() { t.close_salvage_at(slot); }");
        let got = epoch_fence(&[bad]);
        assert_eq!(got.len(), 1);
        let good = source_from_str("x/a.rs", "fn f() { t.close_salvage_at(epoch); }");
        assert!(epoch_fence(&[good]).is_empty());
    }

    #[test]
    fn discarded_reopen_flagged() {
        let bad = source_from_str("x/a.rs", "fn f() { t.reopen(); }");
        assert_eq!(epoch_fence(&[bad]).len(), 1);
        let good = source_from_str("x/a.rs", "fn f() { let e = t.reopen(); }");
        assert!(epoch_fence(&[good]).is_empty());
    }
}
