//! Finding type and the text report renderer shared by the `areal_lint`
//! binary and the self-test suite.

#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: String,
    pub file: String,
    pub line: usize,
    pub msg: String,
}

impl Finding {
    pub fn new(rule: &str, file: &str, line: usize, msg: String) -> Self {
        Finding { rule: rule.to_string(), file: file.to_string(), line, msg }
    }
}

/// Stable order: file, then line, then rule — so CI diffs are meaningful.
pub fn sort(findings: &mut [Finding]) {
    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule.as_str()).cmp(&(b.file.as_str(), b.line, b.rule.as_str()))
    });
}

/// Render the human/CI report: one `file:line: [rule] msg` per finding,
/// then a per-rule tally and the verdict line.
pub fn render(findings: &[Finding]) -> String {
    let mut out = String::new();
    for f in findings {
        out.push_str(&format!("{}:{}: [{}] {}\n", f.file, f.line, f.rule, f.msg));
    }
    let mut rules: Vec<&str> = findings.iter().map(|f| f.rule.as_str()).collect();
    rules.sort();
    rules.dedup();
    if findings.is_empty() {
        out.push_str("areal-lint: clean (0 findings)\n");
    } else {
        out.push('\n');
        for r in rules {
            let n = findings.iter().filter(|f| f.rule == r).count();
            out.push_str(&format!("  {r}: {n}\n"));
        }
        out.push_str(&format!("areal-lint: {} finding(s)\n", findings.len()));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_is_sorted_and_tallied() {
        let mut fs = vec![
            Finding::new("panic", "b.rs", 3, "x".to_string()),
            Finding::new("index", "a.rs", 9, "y".to_string()),
        ];
        sort(&mut fs);
        let r = render(&fs);
        assert!(r.starts_with("a.rs:9: [index] y\n"));
        assert!(r.contains("panic: 1"));
        assert!(r.contains("2 finding(s)"));
    }

    #[test]
    fn clean_report() {
        assert!(render(&[]).contains("clean"));
    }
}
