//! A lightweight Rust token lexer — just enough structure for areal-lint's
//! per-function analyses. Produces a flat token stream (identifiers,
//! numbers, strings, punctuation) with line numbers, plus the set of
//! `// areal-lint: allow(<rule>, ...)` escape hatches keyed by line.
//!
//! Deliberately NOT a full Rust lexer: no parse tree, no macro expansion.
//! Comments and string contents are opaque; raw strings and nested block
//! comments are skipped correctly so line numbers stay exact.

use std::collections::HashMap;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Ident,
    Num,
    Str,
    Char,
    Lifetime,
    Punct,
}

#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: Kind,
    pub text: String,
    pub line: usize,
}

/// Lines carrying `// areal-lint: allow(<rule>, ...)` comments, keyed by
/// the line the comment sits on. An allow covers findings on its own line
/// and on the line immediately below (comment-above form).
pub type Allows = HashMap<usize, Vec<String>>;

pub struct Lexed {
    pub toks: Vec<Tok>,
    pub allows: Allows,
}

fn push(toks: &mut Vec<Tok>, kind: Kind, text: String, line: usize) {
    toks.push(Tok { kind, text, line });
}

pub fn lex(src: &str) -> Lexed {
    let cs: Vec<char> = src.chars().collect();
    let n = cs.len();
    let mut toks: Vec<Tok> = Vec::new();
    let mut allows: Allows = HashMap::new();
    let mut i = 0usize;
    let mut line = 1usize;
    while i < n {
        let c = cs[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // line comment — the only place allow annotations live
        if c == '/' && i + 1 < n && cs[i + 1] == '/' {
            let start = i;
            while i < n && cs[i] != '\n' {
                i += 1;
            }
            let text: String = cs[start..i].iter().collect();
            if let Some(pos) = text.find("areal-lint:") {
                let rest = &text[pos..];
                if let Some(ap) = rest.find("allow(") {
                    let mut rule = String::new();
                    for ch in rest[ap + 6..].chars() {
                        if ch.is_ascii_alphanumeric() || ch == '-' || ch == '_' {
                            rule.push(ch);
                        } else {
                            break;
                        }
                    }
                    if !rule.is_empty() {
                        allows.entry(line).or_default().push(rule);
                    }
                }
            }
            continue;
        }
        // block comment (Rust block comments nest)
        if c == '/' && i + 1 < n && cs[i + 1] == '*' {
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if cs[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if cs[i] == '/' && i + 1 < n && cs[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if cs[i] == '*' && i + 1 < n && cs[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            continue;
        }
        // identifier — or the r"/br" prefix of a raw string
        if c.is_ascii_alphabetic() || c == '_' {
            let start = i;
            while i < n && (cs[i].is_ascii_alphanumeric() || cs[i] == '_') {
                i += 1;
            }
            let text: String = cs[start..i].iter().collect();
            if (text == "r" || text == "br" || text == "b") && i < n {
                // peek for a raw/byte string without consuming
                let mut j = i;
                let mut hashes = 0usize;
                while j < n && cs[j] == '#' {
                    hashes += 1;
                    j += 1;
                }
                let is_raw = (text != "b" || hashes == 0) && j < n && cs[j] == '"';
                if is_raw {
                    let tok_line = line;
                    j += 1; // past opening quote
                    if hashes == 0 && (text == "b") {
                        // byte string b"...": escape-aware scan
                        while j < n {
                            if cs[j] == '\\' {
                                j += 2;
                            } else if cs[j] == '"' {
                                break;
                            } else {
                                if cs[j] == '\n' {
                                    line += 1;
                                }
                                j += 1;
                            }
                        }
                        j += 1;
                    } else {
                        // raw string: ends at quote followed by `hashes` #s
                        loop {
                            if j >= n {
                                break;
                            }
                            if cs[j] == '"' {
                                let mut k = 0usize;
                                while k < hashes && j + 1 + k < n && cs[j + 1 + k] == '#' {
                                    k += 1;
                                }
                                if k == hashes {
                                    j += 1 + hashes;
                                    break;
                                }
                            }
                            if cs[j] == '\n' {
                                line += 1;
                            }
                            j += 1;
                        }
                    }
                    let full: String = cs[start..j.min(n)].iter().collect();
                    push(&mut toks, Kind::Str, full, tok_line);
                    i = j.min(n);
                    continue;
                }
            }
            push(&mut toks, Kind::Ident, text, line);
            continue;
        }
        // number: digits plus alphanumeric/underscore tail (hex, suffixes);
        // '.' excluded so ranges like `0..n` lex as num..num
        if c.is_ascii_digit() {
            let start = i;
            while i < n && (cs[i].is_ascii_alphanumeric() || cs[i] == '_') {
                i += 1;
            }
            let text: String = cs[start..i].iter().collect();
            push(&mut toks, Kind::Num, text, line);
            continue;
        }
        // string literal
        if c == '"' {
            let start = i;
            let tok_line = line;
            i += 1;
            while i < n {
                if cs[i] == '\\' {
                    i += 2;
                } else if cs[i] == '"' {
                    break;
                } else {
                    if cs[i] == '\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            i = (i + 1).min(n);
            let full: String = cs[start..i.min(n)].iter().collect();
            push(&mut toks, Kind::Str, full, tok_line);
            continue;
        }
        // char literal or lifetime
        if c == '\'' {
            // 'a' is a char, 'abc (no closing quote right after) is a lifetime
            if i + 1 < n && (cs[i + 1].is_ascii_alphabetic() || cs[i + 1] == '_') {
                let mut j = i + 1;
                while j < n && (cs[j].is_ascii_alphanumeric() || cs[j] == '_') {
                    j += 1;
                }
                if j == i + 2 && j < n && cs[j] == '\'' {
                    let full: String = cs[i..j + 1].iter().collect();
                    push(&mut toks, Kind::Char, full, line);
                    i = j + 1;
                    continue;
                }
                let full: String = cs[i..j].iter().collect();
                push(&mut toks, Kind::Lifetime, full, line);
                i = j;
                continue;
            }
            // escaped or punctuation char literal: '\n', '\\', '{', ...
            let start = i;
            i += 1;
            if i < n && cs[i] == '\\' {
                i += 2;
            } else {
                i += 1;
            }
            while i < n && cs[i] != '\'' {
                if cs[i] == '\n' {
                    line += 1;
                }
                i += 1;
            }
            i = (i + 1).min(n);
            let full: String = cs[start..i.min(n)].iter().collect();
            push(&mut toks, Kind::Char, full, line);
            continue;
        }
        push(&mut toks, Kind::Punct, c.to_string(), line);
        i += 1;
    }
    Lexed { toks, allows }
}

/// Index of the `#[cfg(test)]` module marker — tokens from there on are
/// test code, exempt from every rule. Returns `toks.len()` if absent.
pub fn test_cut(toks: &[Tok]) -> usize {
    if toks.len() < 6 {
        return toks.len();
    }
    for k in 0..toks.len() - 5 {
        if toks[k].text == "#"
            && toks[k + 1].text == "["
            && toks[k + 2].text == "cfg"
            && toks[k + 3].text == "("
            && toks[k + 4].text == "test"
        {
            let hi = (k + 12).min(toks.len());
            for j in k + 6..hi {
                if toks[j].text == "mod" {
                    return k;
                }
            }
        }
    }
    toks.len()
}

/// An allow on line `ln` or the line above suppresses a finding at `ln`.
pub fn allowed(allows: &Allows, rule: &str, ln: usize) -> bool {
    for probe in [ln, ln.saturating_sub(1)] {
        if let Some(rules) = allows.get(&probe) {
            if rules.iter().any(|r| r == rule) {
                return true;
            }
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lexes_idents_strings_and_comments() {
        let lx = lex("fn a() { let s = \"x,y\"; } // areal-lint: allow(panic, reason=\"z\")\n");
        let idents: Vec<&str> = lx
            .toks
            .iter()
            .filter(|t| t.kind == Kind::Ident)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(idents, vec!["fn", "a", "let", "s"]);
        assert!(allowed(&lx.allows, "panic", 1));
        assert!(allowed(&lx.allows, "panic", 2)); // line-above form
        assert!(!allowed(&lx.allows, "index", 1));
    }

    #[test]
    fn raw_strings_and_lifetimes() {
        let lx = lex("let r = r#\"no \" end\"#; fn f<'a>(x: &'a str) {}");
        let strs: Vec<&str> = lx
            .toks
            .iter()
            .filter(|t| t.kind == Kind::Str)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(strs.len(), 1);
        assert!(strs[0].contains("no \" end"));
        assert!(lx.toks.iter().any(|t| t.kind == Kind::Lifetime));
    }

    #[test]
    fn test_cut_finds_cfg_test_module() {
        let lx = lex("fn a() {}\n#[cfg(test)]\nmod tests { fn b() {} }\n");
        let cut = test_cut(&lx.toks);
        let before: Vec<&str> = lx.toks[..cut].iter().map(|t| t.text.as_str()).collect();
        assert!(before.contains(&"a"));
        assert!(!before.contains(&"b"));
    }

    #[test]
    fn ranges_lex_as_separate_tokens() {
        let lx = lex("let x = &v[0..10];");
        let nums: Vec<&str> = lx
            .toks
            .iter()
            .filter(|t| t.kind == Kind::Num)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(nums, vec!["0", "10"]);
    }
}
