//! Small statistics helpers used by metrics, benches and the simulator.

/// Running mean/variance (Welford).
#[derive(Debug, Clone, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.mean }
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Exponential moving average.
#[derive(Debug, Clone)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        Self { alpha, value: None }
    }

    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(v) => v + self.alpha * (x - v),
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() { return f64::NAN; }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 { return 0.0; }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Percentile with linear interpolation; p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() { return f64::NAN; }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Mean/std normalization in place; no-op on len < 2 or zero variance.
pub fn normalize(xs: &mut [f64]) {
    if xs.len() < 2 { return; }
    let m = mean(xs);
    let s = std(xs);
    if s < 1e-9 {
        for x in xs.iter_mut() { *x -= m; }
    } else {
        for x in xs.iter_mut() { *x = (*x - m) / s; }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_matches_batch() {
        let xs = [1.0, 2.0, 4.0, 8.0];
        let mut r = Running::new();
        for &x in &xs { r.push(x); }
        assert!((r.mean() - mean(&xs)).abs() < 1e-12);
        assert!((r.std() - std(&xs)).abs() < 1e-12);
        assert_eq!(r.min(), 1.0);
        assert_eq!(r.max(), 8.0);
    }

    #[test]
    fn percentile_interp() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn normalize_unit() {
        let mut xs = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        normalize(&mut xs);
        assert!(mean(&xs).abs() < 1e-12);
        assert!((std(&xs) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn normalize_constant_vector() {
        let mut xs = vec![3.0, 3.0, 3.0];
        normalize(&mut xs);
        assert!(xs.iter().all(|x| x.abs() < 1e-12));
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        for _ in 0..30 { e.push(10.0); }
        assert!((e.get().unwrap() - 10.0).abs() < 1e-6);
    }
}
