//! Leveled logger + CSV metric writer (no `log`/`env_logger` needed).
//!
//! The logger is process-global, cheap, and honors `AREAL_LOG`
//! (error|warn|info|debug|trace). Metric series are written as CSV so the
//! experiment drivers can regenerate the paper's figures from files.

use std::fmt;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        write!(f, "{s}")
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(2); // Info
static START: OnceLock<Instant> = OnceLock::new();
static SINK: Mutex<()> = Mutex::new(());

pub fn init_from_env() {
    if let Ok(v) = std::env::var("AREAL_LOG") {
        let lvl = match v.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "info" => Level::Info,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        };
        set_level(lvl);
    }
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(l: Level, target: &str, msg: fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let _g = SINK.lock().unwrap();
    eprintln!("[{t:9.3}s {l} {target}] {msg}");
}

#[macro_export]
macro_rules! info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, $target,
                                   format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn_log {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, $target,
                                   format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, $target,
                                   format_args!($($arg)*))
    };
}

/// CSV writer for metric series (one header, rows of f64).
pub struct CsvWriter {
    w: BufWriter<File>,
    cols: usize,
}

impl CsvWriter {
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> std::io::Result<Self> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut w = BufWriter::new(File::create(path)?);
        writeln!(w, "{}", header.join(","))?;
        Ok(Self { w, cols: header.len() })
    }

    pub fn row(&mut self, values: &[f64]) -> std::io::Result<()> {
        assert_eq!(values.len(), self.cols, "csv row arity mismatch");
        let line: Vec<String> = values.iter().map(|v| format!("{v}")).collect();
        writeln!(self.w, "{}", line.join(","))
    }

    pub fn row_mixed(&mut self, label: &str, values: &[f64]) -> std::io::Result<()> {
        assert_eq!(values.len() + 1, self.cols, "csv row arity mismatch");
        let line: Vec<String> = values.iter().map(|v| format!("{v}")).collect();
        writeln!(self.w, "{},{}", label, line.join(","))
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("areal_csv_test");
        let path = dir.join("m.csv");
        let mut w = CsvWriter::create(&path, &["step", "loss"]).unwrap();
        w.row(&[1.0, 0.5]).unwrap();
        w.row(&[2.0, 0.25]).unwrap();
        w.flush().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 3);
        assert!(text.starts_with("step,loss"));
    }
}
