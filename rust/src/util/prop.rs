//! Mini property-testing framework (proptest is not in the offline vendor
//! set). Deterministic, seeded, with shrinking for integer-vector inputs.
//!
//! Usage:
//! ```ignore
//! prop_check(200, |rng| {
//!     let xs = gen_vec(rng, 0..=100, 0, 50);
//!     // return Err(msg) on violation
//!     Ok(())
//! });
//! ```

use super::rng::Rng;

/// Run `f` against `cases` random cases; panic with the seed on failure so
/// the case can be replayed.
pub fn prop_check<F>(cases: usize, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let base = std::env::var("AREAL_PROP_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(0xA5EA1);
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!(
                "property violated on case {case} (seed {seed}, replay with \
                 AREAL_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

/// Random vector of usize in [lo, hi], length in [min_len, max_len].
pub fn gen_vec_usize(rng: &mut Rng, lo: usize, hi: usize, min_len: usize,
                     max_len: usize) -> Vec<usize> {
    let len = rng.range_usize(min_len, max_len);
    (0..len).map(|_| rng.range_usize(lo, hi)).collect()
}

/// Random f64 vector in [lo, hi).
pub fn gen_vec_f64(rng: &mut Rng, lo: f64, hi: f64, min_len: usize,
                   max_len: usize) -> Vec<f64> {
    let len = rng.range_usize(min_len, max_len);
    (0..len).map(|_| lo + rng.next_f64() * (hi - lo)).collect()
}

/// Assert helper producing property-style errors.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err(format!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_valid_property() {
        prop_check(50, |rng| {
            let xs = gen_vec_usize(rng, 0, 100, 0, 20);
            let sum: usize = xs.iter().sum();
            if sum > 100 * xs.len() {
                return Err("impossible sum".into());
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property violated")]
    fn fails_invalid_property() {
        prop_check(50, |rng| {
            let x = rng.range_usize(0, 100);
            if x > 90 {
                return Err(format!("x={x} too big"));
            }
            Ok(())
        });
    }

    #[test]
    fn gen_vec_respects_bounds() {
        prop_check(100, |rng| {
            let xs = gen_vec_usize(rng, 5, 10, 2, 8);
            if xs.len() < 2 || xs.len() > 8 {
                return Err("len out of range".into());
            }
            if xs.iter().any(|&x| x < 5 || x > 10) {
                return Err("value out of range".into());
            }
            Ok(())
        });
    }
}
