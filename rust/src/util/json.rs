//! Minimal JSON parser/serializer.
//!
//! The offline build environment has no `serde`, so the config system and
//! the artifact manifest loader use this hand-rolled implementation. It
//! supports the full JSON grammar except `\u` surrogate pairs beyond the
//! BMP (sufficient for manifest.json and config files, which are ASCII).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- accessors -----------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as usize) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj.get("a.b.c")`-style nested lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        let mut cur = self;
        for part in key.split('.') {
            cur = cur.as_obj()?.get(part)?;
        }
        Some(cur)
    }

    pub fn get_f64(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(Json::as_f64)
    }

    pub fn get_usize(&self, key: &str) -> Option<usize> {
        self.get(key).and_then(Json::as_usize)
    }

    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(Json::as_str)
    }

    // -- builders -------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.pos += 1;
        Some(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(arr)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        if self.pos + 4 > self.b.len() {
                            return Err(self.err("bad \\u escape"));
                        }
                        let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
                            .map_err(|_| self.err("bad \\u escape"))?;
                        let cp = u32::from_str_radix(hex, 16)
                            .map_err(|_| self.err("bad \\u escape"))?;
                        self.pos += 4;
                        out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // multi-byte utf-8: copy the remaining continuation bytes
                    let n = if c >= 0xf0 { 3 } else if c >= 0xe0 { 2 } else { 1 };
                    let start = self.pos - 1;
                    self.pos += n;
                    let s = std::str::from_utf8(&self.b[start..self.pos])
                        .map_err(|_| self.err("bad utf-8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": {"b": [1, 2, {"c": "x"}]}, "d": false}"#).unwrap();
        assert_eq!(v.get("a.b").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("d").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn roundtrip() {
        let s = r#"{"arr":[1,2.5,"x"],"n":null,"o":{"k":true}}"#;
        let v = Json::parse(s).unwrap();
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn utf8_passthrough() {
        assert_eq!(Json::parse("\"héllo→\"").unwrap(), Json::Str("héllo→".into()));
    }

    #[test]
    fn nested_get_path() {
        let v = Json::parse(r#"{"x":{"y":{"z":7}}}"#).unwrap();
        assert_eq!(v.get_usize("x.y.z"), Some(7));
        assert!(v.get("x.q").is_none());
    }
}
