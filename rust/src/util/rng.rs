//! PRNG + sampling helpers (no `rand` crate in the offline vendor set).
//!
//! `SplitMix64` for seeding, `Xoshiro256StarStar` as the workhorse generator
//! — the same construction the reference `rand` crate uses. Deterministic
//! and seedable everywhere so experiments are reproducible with the paper's
//! fixed-seed protocol (Appendix A: "a fixed random seed of 1").

#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — fast, high-quality, 256-bit state.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    /// Derive an independent stream (for per-worker rngs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9e3779b97f4a7c15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n). Rejection-free Lemire reduction.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_i64(lo as i64, hi as i64) as usize
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Lognormal with the given mu/sigma of the underlying normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with rate lambda.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.next_f64().max(1e-300).ln() / lambda
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical needs positive total weight");
        let mut x = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Two u32 words for the AOT threefry seed inputs.
    pub fn jax_seed(&mut self) -> [u32; 2] {
        [self.next_u32(), self.next_u32()]
    }
}

/// Softmax-sample from f32 logits with temperature (host-side sampler used
/// by eval tooling; the training path samples in-graph).
pub fn sample_logits(rng: &mut Rng, logits: &[f32], temp: f32) -> usize {
    if temp < 1e-3 {
        return argmax(logits);
    }
    let inv = 1.0 / temp as f64;
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let weights: Vec<f64> = logits
        .iter()
        .map(|&l| ((l as f64 - m) * inv).exp())
        .collect();
    rng.categorical(&weights)
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_mean() {
        let mut rng = Rng::new(7);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn below_is_in_range() {
        let mut rng = Rng::new(3);
        for _ in 0..10_000 {
            assert!(rng.below(17) < 17);
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn categorical_distribution() {
        let mut rng = Rng::new(5);
        let w = [1.0, 2.0, 7.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[rng.categorical(&w)] += 1;
        }
        assert!((counts[2] as f64 / 10_000.0 - 0.7).abs() < 0.03);
        assert!((counts[0] as f64 / 10_000.0 - 0.1).abs() < 0.02);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(9);
        let mut xs: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_logits_greedy() {
        let mut rng = Rng::new(1);
        assert_eq!(sample_logits(&mut rng, &[0.1, 5.0, -2.0], 0.0), 1);
    }

    #[test]
    fn sample_logits_temperature() {
        let mut rng = Rng::new(1);
        // huge gap => sampling still picks the max virtually always
        let mut hits = 0;
        for _ in 0..100 {
            if sample_logits(&mut rng, &[0.0, 20.0], 1.0) == 1 {
                hits += 1;
            }
        }
        assert!(hits >= 99);
    }

    #[test]
    fn fork_streams_differ() {
        let mut root = Rng::new(1);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
