//! Shared substrate utilities: JSON, PRNG, statistics, logging, thread pool,
//! benchmark harness, property-test framework. All hand-rolled — the offline
//! vendor set has no serde/rand/rayon/criterion/proptest.

pub mod json;
pub mod logging;
pub mod metrics;
pub mod minibench;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod sync;
pub mod threadpool;
