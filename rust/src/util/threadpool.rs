//! Fixed-size thread pool (no rayon/tokio in the offline vendor set).
//!
//! Used by the reward service to run verification off the decode thread —
//! the paper's §6 "decouple GPU computation from CPU operations ... by
//! executing these operations in separate threads".

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    tx: Option<mpsc::Sender<Job>>,
}

impl ThreadPool {
    pub fn new(n: usize, name: &str) -> Self {
        assert!(n > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let rx = Arc::clone(&rx);
            let handle = thread::Builder::new()
                .name(format!("{name}-{i}"))
                .spawn(move || loop {
                    let job = { rx.lock().unwrap().recv() };
                    match job {
                        Ok(job) => job(),
                        Err(_) => break, // sender dropped: shut down
                    }
                })
                .expect("spawn pool thread");
            workers.push(handle);
        }
        Self { workers, tx: Some(tx) }
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("pool receiver gone");
    }

    /// Run `f` over items on the pool and collect results (in input order).
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel();
        let n = items.len();
        for (i, item) in items.into_iter().enumerate() {
            let tx = tx.clone();
            let f = Arc::clone(&f);
            self.execute(move || {
                let r = f(item);
                let _ = tx.send((i, r));
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, r) = rx.recv().expect("pool worker panicked");
            out[i] = Some(r);
        }
        out.into_iter().map(|o| o.unwrap()).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close channel; workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4, "test");
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..100 {
            rx.recv().unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3, "test");
        let out = pool.map((0..50).collect::<Vec<i32>>(), |x| x * 2);
        assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<i32>>());
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2, "test");
        pool.execute(|| std::thread::sleep(std::time::Duration::from_millis(10)));
        drop(pool); // must not hang or panic
    }
}
