//! Process-global, lock-light metrics registry (ISSUE 6 tentpole): the
//! telemetry plane every subsystem reports into.
//!
//! Three instrument kinds, all writable concurrently without stopping
//! writers or taking the registry lock on the hot path:
//!
//! - [`Counter`]: a monotone `AtomicU64`;
//! - [`Gauge`]: an `f64` stored as atomic bits (last-write-wins);
//! - [`Histogram`]: fixed log-scale buckets (4 sub-buckets per octave
//!   covering ~2⁻²⁰..2⁴⁴, i.e. microseconds to days when the unit is
//!   seconds) of `AtomicU64` counts, with p50/p90/p99 extraction by
//!   cumulative-rank walk + intra-bucket linear interpolation, mirroring
//!   `util/stats.rs::percentile`'s `rank = (p/100)·(n−1)` convention.
//!
//! Metrics are named; a label set is carried *in* the name
//! (`areal_ttft_seconds{policy="probe"}`) so the registry stays a flat
//! string-keyed map. Registration takes a `Mutex` once per name; hot
//! writers hold a cached `Arc` handle and pay one relaxed atomic op per
//! write. The whole plane is gated by a process-global enable flag,
//! default **off**: with metrics off every write is a relaxed load + a
//! branch, so benches and library users who never call [`set_enabled`]
//! pay noise-level overhead. Call sites that would otherwise pay for
//! timestamps should guard them with [`enabled`].
//!
//! Exporters:
//! - [`to_prometheus`]: Prometheus text exposition (counters, gauges, and
//!   histograms as summaries with `quantile` labels);
//! - [`to_jsonl`]: one JSON object per snapshot, for the
//!   `out_dir/metrics_live.jsonl` stream;
//! - [`MetricsServer`]: a loopback `GET /metrics` listener;
//! - [`JsonlExporter`]: the periodic snapshot thread.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::util::json::Json;

// ---------------------------------------------------------------------
// instruments

/// Monotone counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        if enabled() {
            self.0.fetch_add(n, Ordering::Relaxed);
        }
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins f64 gauge (bits in an `AtomicU64`).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: f64) {
        if enabled() {
            self.0.store(v.to_bits(), Ordering::Relaxed);
        }
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Log-scale histogram geometry: SUB sub-buckets per octave over
/// [2^MIN_EXP, 2^(MIN_EXP + NB/SUB)). Bucket width is 2^(1/SUB) ≈ 1.19×,
/// which bounds the relative error of percentile extraction.
const SUB: usize = 4;
const NB: usize = 256;
const MIN_EXP: f64 = -20.0;

fn bucket_of(v: f64) -> usize {
    if v <= 0.0 || !v.is_finite() {
        return 0;
    }
    let i = ((v.log2() - MIN_EXP) * SUB as f64).floor() as i64;
    i.clamp(0, NB as i64 - 1) as usize
}

fn bucket_lo(i: usize) -> f64 {
    (MIN_EXP + i as f64 / SUB as f64).exp2()
}

/// Fixed-bucket log-scale histogram, writable by any number of threads
/// concurrently and snapshot-able without stopping them.
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    /// f64 bits, CAS-accumulated
    sum: AtomicU64,
    /// f64 bits, CAS-min / CAS-max
    min: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: (0..NB).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0.0f64.to_bits()),
            min: AtomicU64::new(f64::INFINITY.to_bits()),
            max: AtomicU64::new(f64::NEG_INFINITY.to_bits()),
        }
    }

    pub fn observe(&self, v: f64) {
        if !enabled() || !v.is_finite() {
            return;
        }
        self.record(v);
    }

    /// Unconditional record (tests and oracles; normal call sites use
    /// [`Histogram::observe`], which respects the global enable flag).
    pub fn record(&self, v: f64) {
        self.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        cas_f64(&self.sum, |s| s + v);
        cas_f64(&self.min, |m| m.min(v));
        cas_f64(&self.max, |m| m.max(v));
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn snapshot(&self) -> HistSnapshot {
        // bucket reads race with writers; each read is atomic, so the
        // snapshot is a slightly-torn but well-formed view (percentiles
        // use the bucket sum, so they are self-consistent)
        HistSnapshot {
            buckets: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            sum: f64::from_bits(self.sum.load(Ordering::Relaxed)),
            min: f64::from_bits(self.min.load(Ordering::Relaxed)),
            max: f64::from_bits(self.max.load(Ordering::Relaxed)),
        }
    }
}

fn cas_f64(a: &AtomicU64, f: impl Fn(f64) -> f64) {
    let mut cur = a.load(Ordering::Relaxed);
    loop {
        let next = f(f64::from_bits(cur)).to_bits();
        match a.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(now) => cur = now,
        }
    }
}

/// Point-in-time view of one histogram.
#[derive(Debug, Clone)]
pub struct HistSnapshot {
    pub buckets: Vec<u64>,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl HistSnapshot {
    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            f64::NAN
        } else {
            self.sum / n as f64
        }
    }

    /// Percentile by cumulative-rank walk with intra-bucket linear
    /// interpolation — `stats::percentile`'s `rank = (p/100)·(n−1)`
    /// convention, accurate to one bucket width (≈19% relative). The
    /// extremes are exact: p=0 returns the tracked min, p=100 the max.
    pub fn percentile(&self, p: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return f64::NAN;
        }
        if p <= 0.0 {
            return self.min;
        }
        if p >= 100.0 {
            return self.max;
        }
        let rank = (p / 100.0) * (n - 1) as f64;
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if rank < (cum + c) as f64 {
                let frac = (rank - cum as f64) / c as f64;
                let lo = bucket_lo(i).max(self.min);
                let hi = bucket_lo(i + 1).min(self.max);
                let (lo, hi) = if lo <= hi { (lo, hi) } else { (hi, lo) };
                return lo + frac * (hi - lo);
            }
            cum += c;
        }
        self.max
    }
}

// ---------------------------------------------------------------------
// registry

// const-constructible statics — no lazy-init machinery needed
// (`Mutex::new` and `BTreeMap::new` are both const fns)
static ENABLED: AtomicBool = AtomicBool::new(false);
static COUNTERS: Mutex<BTreeMap<String, Arc<Counter>>> = Mutex::new(BTreeMap::new());
static GAUGES: Mutex<BTreeMap<String, Arc<Gauge>>> = Mutex::new(BTreeMap::new());
static HISTS: Mutex<BTreeMap<String, Arc<Histogram>>> = Mutex::new(BTreeMap::new());

/// Is the telemetry plane recording? Call sites that would pay for a
/// timestamp or a label `format!` should check this first.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn the whole plane on or off (process-global; default off).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Register-or-get a counter handle (one registry lock per call — cache
/// the handle on hot paths).
pub fn counter(name: &str) -> Arc<Counter> {
    let mut m = COUNTERS.lock().unwrap();
    Arc::clone(m.entry(name.to_string()).or_default())
}

pub fn gauge(name: &str) -> Arc<Gauge> {
    let mut m = GAUGES.lock().unwrap();
    Arc::clone(m.entry(name.to_string()).or_default())
}

pub fn histogram(name: &str) -> Arc<Histogram> {
    let mut m = HISTS.lock().unwrap();
    Arc::clone(m.entry(name.to_string()).or_default())
}

/// Convenience one-shot writes for cold call sites (per-trajectory,
/// per-step). They early-return with metrics off, before any lock.
pub fn inc(name: &str, n: u64) {
    if enabled() {
        counter(name).add(n);
    }
}

pub fn set(name: &str, v: f64) {
    if enabled() {
        gauge(name).set(v);
    }
}

pub fn observe(name: &str, v: f64) {
    if enabled() {
        histogram(name).observe(v);
    }
}

/// Point-in-time view of the whole registry, taken without stopping
/// writers (each map lock is held only to clone the `Arc` list).
#[derive(Debug, Clone, Default)]
pub struct Snapshot {
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, f64)>,
    pub hists: Vec<(String, HistSnapshot)>,
}

impl Snapshot {
    pub fn counter(&self, name: &str) -> Option<u64> {
        self.counters.iter().find(|(k, _)| k == name).map(|(_, v)| *v)
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.iter().find(|(k, _)| k == name).map(|(_, v)| *v)
    }

    pub fn hist(&self, name: &str) -> Option<&HistSnapshot> {
        self.hists.iter().find(|(k, _)| k == name).map(|(_, h)| h)
    }
}

pub fn snapshot() -> Snapshot {
    let counters: Vec<(String, Arc<Counter>)> = {
        let m = COUNTERS.lock().unwrap();
        m.iter().map(|(k, v)| (k.clone(), Arc::clone(v))).collect()
    };
    let gauges: Vec<(String, Arc<Gauge>)> = {
        let m = GAUGES.lock().unwrap();
        m.iter().map(|(k, v)| (k.clone(), Arc::clone(v))).collect()
    };
    let hists: Vec<(String, Arc<Histogram>)> = {
        let m = HISTS.lock().unwrap();
        m.iter().map(|(k, v)| (k.clone(), Arc::clone(v))).collect()
    };
    Snapshot {
        counters: counters.into_iter().map(|(k, c)| (k, c.get())).collect(),
        gauges: gauges.into_iter().map(|(k, g)| (k, g.get())).collect(),
        hists: hists.into_iter().map(|(k, h)| (k, h.snapshot())).collect(),
    }
}

// ---------------------------------------------------------------------
// exposition

fn split_labels(name: &str) -> (&str, Option<&str>) {
    match name.split_once('{') {
        Some((base, rest)) => (base, Some(rest.trim_end_matches('}'))),
        None => (name, None),
    }
}

fn series(name: &str, extra: Option<&str>) -> String {
    let (base, labels) = split_labels(name);
    match (labels, extra) {
        (None, None) => base.to_string(),
        (Some(l), None) => format!("{base}{{{l}}}"),
        (None, Some(e)) => format!("{base}{{{e}}}"),
        (Some(l), Some(e)) => format!("{base}{{{l},{e}}}"),
    }
}

fn label_suffix(name: &str) -> String {
    match split_labels(name) {
        (_, Some(l)) => format!("{{{l}}}"),
        (_, None) => String::new(),
    }
}

/// Prometheus text exposition format, version 0.0.4. Histograms render as
/// summaries (quantile series + `_sum` + `_count`). Series sharing a base
/// name (label variants) get one `# TYPE` line thanks to sorted iteration.
pub fn to_prometheus(s: &Snapshot) -> String {
    fn typed(
        out: &mut String,
        last: &mut Option<(String, &'static str)>,
        base: &str,
        kind: &'static str,
    ) {
        let same = match last {
            Some((b, k)) => b == base && *k == kind,
            None => false,
        };
        if !same {
            out.push_str(&format!("# TYPE {base} {kind}\n"));
            *last = Some((base.to_string(), kind));
        }
    }
    let mut out = String::new();
    let mut last_type: Option<(String, &'static str)> = None;
    for (name, v) in &s.counters {
        let (base, _) = split_labels(name);
        typed(&mut out, &mut last_type, base, "counter");
        out.push_str(&format!("{} {v}\n", series(name, None)));
    }
    for (name, v) in &s.gauges {
        let (base, _) = split_labels(name);
        typed(&mut out, &mut last_type, base, "gauge");
        out.push_str(&format!("{} {}\n", series(name, None), sanitize(*v)));
    }
    for (name, h) in &s.hists {
        let (base, _) = split_labels(name);
        typed(&mut out, &mut last_type, base, "summary");
        for (q, p) in [("0.5", 50.0), ("0.9", 90.0), ("0.99", 99.0)] {
            out.push_str(&format!(
                "{} {}\n",
                series(name, Some(&format!("quantile=\"{q}\""))),
                sanitize(h.percentile(p))
            ));
        }
        out.push_str(&format!(
            "{}_sum{} {}\n",
            base,
            label_suffix(name),
            sanitize(h.sum)
        ));
        out.push_str(&format!(
            "{}_count{} {}\n",
            base,
            label_suffix(name),
            h.count()
        ));
    }
    out
}

/// One JSONL line: `{"t":…, "counters":{…}, "gauges":{…}, "hists":{name:
/// {"count","mean","p50","p90","p99","max"}}}`.
pub fn to_jsonl(s: &Snapshot, t_s: f64) -> String {
    let counters = Json::obj(
        s.counters
            .iter()
            .map(|(k, v)| (k.as_str(), Json::num(*v as f64)))
            .collect::<Vec<_>>(),
    );
    let gauges = Json::obj(
        s.gauges
            .iter()
            .map(|(k, v)| (k.as_str(), Json::num(sanitize(*v))))
            .collect::<Vec<_>>(),
    );
    let hists = Json::obj(
        s.hists
            .iter()
            .map(|(k, h)| {
                (
                    k.as_str(),
                    Json::obj(vec![
                        ("count", Json::num(h.count() as f64)),
                        ("mean", Json::num(sanitize(h.mean()))),
                        ("p50", Json::num(sanitize(h.percentile(50.0)))),
                        ("p90", Json::num(sanitize(h.percentile(90.0)))),
                        ("p99", Json::num(sanitize(h.percentile(99.0)))),
                        ("max", Json::num(sanitize(h.max))),
                    ]),
                )
            })
            .collect::<Vec<_>>(),
    );
    Json::obj(vec![
        ("t", Json::num(t_s)),
        ("counters", counters),
        ("gauges", gauges),
        ("hists", hists),
    ])
    .to_string()
}

fn sanitize(v: f64) -> f64 {
    if v.is_finite() {
        v
    } else {
        0.0
    }
}

/// Human end-of-run summary: every counter and gauge, plus
/// count/mean/p50/p99/max per histogram.
pub fn render_summary(s: &Snapshot) -> String {
    let mut out = String::new();
    if s.counters.is_empty() && s.gauges.is_empty() && s.hists.is_empty() {
        return out;
    }
    out.push_str("-- telemetry summary ------------------------------------\n");
    for (k, v) in &s.counters {
        out.push_str(&format!("  {k:<44} {v}\n"));
    }
    for (k, v) in &s.gauges {
        out.push_str(&format!("  {k:<44} {:.4}\n", sanitize(*v)));
    }
    for (k, h) in &s.hists {
        if h.count() == 0 {
            continue;
        }
        out.push_str(&format!(
            "  {k:<44} n={} mean={:.4} p50={:.4} p99={:.4} max={:.4}\n",
            h.count(),
            h.mean(),
            h.percentile(50.0),
            h.percentile(99.0),
            h.max
        ));
    }
    out
}

// ---------------------------------------------------------------------
// exporters

/// A callback run just before every snapshot is taken, so point-in-time
/// gauges (gate headroom, inbox depth) are fresh in each export.
pub type PollFn = Arc<dyn Fn() + Send + Sync>;

/// Loopback `GET /metrics` endpoint (Prometheus text format).
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

const HTTP_TICK: Duration = Duration::from_millis(25);

impl MetricsServer {
    /// Bind `addr` (e.g. `127.0.0.1:0`) and serve until
    /// [`MetricsServer::stop`].
    pub fn serve(addr: &str, poll: Option<PollFn>) -> std::io::Result<MetricsServer> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name(format!("metrics-http-{}", addr.port()))
            .spawn(move || {
                while !stop2.load(Ordering::Acquire) {
                    match listener.accept() {
                        Ok((stream, _)) => serve_scrape(stream, poll.as_ref()),
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(HTTP_TICK);
                        }
                        Err(_) => return,
                    }
                }
            })
            .expect("spawn metrics server");
        Ok(MetricsServer { addr, stop, handle: Some(handle) })
    }

    /// The bound address (useful with port 0).
    pub fn local_addr(&self) -> String {
        self.addr.to_string()
    }

    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn serve_scrape(mut stream: TcpStream, poll: Option<&PollFn>) {
    // the accepted socket may inherit the listener's nonblocking mode
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(500)));
    // read the request head (the request line is all we route on)
    let mut buf = Vec::new();
    let mut chunk = [0u8; 1024];
    loop {
        match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => {
                buf.extend_from_slice(&chunk[..n]);
                if buf.windows(4).any(|w| w == b"\r\n\r\n") || buf.len() > 16 * 1024 {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    let head = String::from_utf8_lossy(&buf);
    let path = head.split_whitespace().nth(1).unwrap_or("");
    let (status, body) = if path == "/metrics" || path.starts_with("/metrics?") {
        if let Some(p) = poll {
            p();
        }
        ("200 OK", to_prometheus(&snapshot()))
    } else {
        ("404 Not Found", String::from("not found\n"))
    };
    let resp = format!(
        "HTTP/1.1 {status}\r\nContent-Type: text/plain; version=0.0.4\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    let _ = stream.write_all(resp.as_bytes());
    let _ = stream.flush();
}

/// Scrape `GET /metrics` from `addr`, returning the body (test oracle and
/// the end-of-run scrape the CI job archives).
pub fn scrape(addr: &str) -> std::io::Result<String> {
    let mut stream = TcpStream::connect(addr)?;
    let _ = stream.set_read_timeout(Some(Duration::from_secs(5)));
    stream.write_all(
        format!("GET /metrics HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n")
            .as_bytes(),
    )?;
    let mut out = String::new();
    stream.read_to_string(&mut out)?;
    match out.split_once("\r\n\r\n") {
        Some((_, body)) => Ok(body.to_string()),
        None => Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "no http body in scrape reply",
        )),
    }
}

/// Periodic snapshot thread appending JSONL to a file. A final snapshot is
/// always written at [`JsonlExporter::stop`], so even a run shorter than
/// one interval produces a line.
pub struct JsonlExporter {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl JsonlExporter {
    pub fn start(path: PathBuf, interval: Duration, poll: Option<PollFn>) -> JsonlExporter {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("metrics-jsonl".into())
            .spawn(move || {
                let t0 = Instant::now();
                if let Some(dir) = path.parent() {
                    let _ = std::fs::create_dir_all(dir);
                }
                let mut file = std::fs::OpenOptions::new()
                    .create(true)
                    .append(true)
                    .open(&path)
                    .ok();
                let tick = Duration::from_millis(20).min(interval);
                let mut next = t0 + interval;
                loop {
                    let stopping = stop2.load(Ordering::Acquire);
                    if stopping || Instant::now() >= next {
                        if let Some(p) = &poll {
                            p();
                        }
                        if let Some(f) = file.as_mut() {
                            let line = to_jsonl(&snapshot(), t0.elapsed().as_secs_f64());
                            let _ = writeln!(f, "{line}");
                            let _ = f.flush();
                        }
                        if stopping {
                            return;
                        }
                        next = Instant::now() + interval;
                    }
                    std::thread::sleep(tick);
                }
            })
            .expect("spawn jsonl exporter");
        JsonlExporter { stop, handle: Some(handle) }
    }

    /// Write one final snapshot and join the thread.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for JsonlExporter {
    fn drop(&mut self) {
        self.stop();
    }
}

// ---------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::stats;

    // NOTE: the enable flag is process-global and unit tests run in
    // parallel threads, so tests here only ever turn it ON (idempotent) —
    // the disabled path is covered race-free in `rust/tests/metrics_live.rs`
    // before that binary enables the plane.

    #[test]
    fn counter_and_gauge_roundtrip() {
        set_enabled(true);
        let c = counter("test_ctr_roundtrip");
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        assert_eq!(counter("test_ctr_roundtrip").get(), 5, "same handle by name");
        let g = gauge("test_gauge_roundtrip");
        g.set(2.5);
        assert_eq!(g.get(), 2.5);
    }

    #[test]
    fn histogram_percentiles_match_oracle_single_threaded() {
        let h = Histogram::new();
        let mut rng = Rng::new(42);
        let mut xs = Vec::new();
        for _ in 0..5000 {
            // log-uniform over ~4 decades, the latency shape we care about
            let v = (rng.next_f64() * 12.0 - 6.0).exp2();
            xs.push(v);
            h.record(v);
        }
        let snap = h.snapshot();
        assert_eq!(snap.count(), 5000);
        for p in [50.0, 90.0, 99.0] {
            let want = stats::percentile(&xs, p);
            let got = snap.percentile(p);
            let rel = (got - want).abs() / want;
            // one bucket is 2^(1/4) ≈ 1.19x wide; allow one full bucket
            assert!(rel < 0.20, "p{p}: got {got} want {want} (rel err {rel:.3})");
        }
        assert!((snap.mean() - stats::mean(&xs)).abs() / stats::mean(&xs) < 1e-9);
        assert_eq!(snap.percentile(0.0), snap.min);
        assert_eq!(snap.percentile(100.0), snap.max);
    }

    #[test]
    fn histogram_concurrent_writers_match_oracle() {
        // ISSUE 6 satellite: N threads push, snapshot percentiles match a
        // single-threaded oracle within bucket resolution
        let h = Arc::new(Histogram::new());
        let n_threads = 8;
        let per = 2000;
        let mut oracle = Vec::new();
        for t in 0..n_threads {
            let mut rng = Rng::new(1000 + t as u64);
            for _ in 0..per {
                oracle.push((rng.next_f64() * 10.0 - 5.0).exp2());
            }
        }
        let handles: Vec<_> = (0..n_threads)
            .map(|t| {
                let h = Arc::clone(&h);
                std::thread::spawn(move || {
                    let mut rng = Rng::new(1000 + t as u64);
                    for _ in 0..per {
                        h.record((rng.next_f64() * 10.0 - 5.0).exp2());
                    }
                })
            })
            .collect();
        for th in handles {
            th.join().unwrap();
        }
        let snap = h.snapshot();
        assert_eq!(snap.count(), (n_threads * per) as u64);
        for p in [50.0, 90.0, 99.0] {
            let want = stats::percentile(&oracle, p);
            let got = snap.percentile(p);
            let rel = (got - want).abs() / want;
            assert!(rel < 0.20, "p{p}: got {got} want {want} (rel err {rel:.3})");
        }
        let want_sum: f64 = oracle.iter().sum();
        assert!((snap.sum - want_sum).abs() / want_sum < 1e-9, "CAS sum is exact");
    }

    #[test]
    fn snapshot_while_writing_is_safe_and_monotone() {
        // ISSUE 6 satellite: snapshots race live writers without panics,
        // and every observed count is monotone non-decreasing
        set_enabled(true);
        let stop = Arc::new(AtomicBool::new(false));
        let writers: Vec<_> = (0..4)
            .map(|t| {
                let stop = Arc::clone(&stop);
                std::thread::spawn(move || {
                    let c = counter("test_snap_race_ctr");
                    let h = histogram("test_snap_race_hist");
                    let mut rng = Rng::new(7 + t as u64);
                    while !stop.load(Ordering::Acquire) {
                        c.inc();
                        h.observe(rng.next_f64() + 0.01);
                    }
                })
            })
            .collect();
        let mut last_c = 0u64;
        let mut last_h = 0u64;
        for _ in 0..200 {
            let s = snapshot();
            let c = s.counter("test_snap_race_ctr").unwrap_or(0);
            let hc = s.hist("test_snap_race_hist").map_or(0, |h| h.count());
            assert!(c >= last_c, "counter went backwards: {c} < {last_c}");
            assert!(hc >= last_h, "hist count went backwards");
            last_c = c;
            last_h = hc;
        }
        stop.store(true, Ordering::Release);
        for w in writers {
            w.join().unwrap();
        }
        assert!(last_c > 0, "writers made progress under snapshots");
    }

    #[test]
    fn prometheus_exposition_format() {
        set_enabled(true);
        counter("test_promfmt_total{policy=\"probe\"}").add(3);
        gauge("test_promfmt_gauge").set(1.5);
        let h = histogram("test_promfmt_lat{policy=\"probe\"}");
        for i in 1..=100 {
            h.observe(i as f64 / 100.0);
        }
        let text = to_prometheus(&snapshot());
        assert!(text.contains("# TYPE test_promfmt_total counter"));
        assert!(text.contains("test_promfmt_total{policy=\"probe\"} 3"));
        assert!(text.contains("# TYPE test_promfmt_gauge gauge"));
        assert!(text.contains("test_promfmt_gauge 1.5"));
        assert!(text.contains("# TYPE test_promfmt_lat summary"));
        assert!(
            text.contains("test_promfmt_lat{policy=\"probe\",quantile=\"0.5\"}"),
            "quantile label merges into the existing label set:\n{text}"
        );
        assert!(text.contains("test_promfmt_lat_count{policy=\"probe\"} 100"));
    }

    #[test]
    fn jsonl_line_parses_back() {
        set_enabled(true);
        counter("test_jsonl_ctr").add(2);
        histogram("test_jsonl_hist").observe(0.25);
        let line = to_jsonl(&snapshot(), 1.25);
        let j = Json::parse(&line).expect("jsonl line parses");
        assert_eq!(j.get_f64("t"), Some(1.25));
        assert!(
            j.get("counters").and_then(|c| c.get_f64("test_jsonl_ctr")).unwrap() >= 2.0
        );
        let h = j.get("hists").and_then(|h| h.get("test_jsonl_hist")).unwrap();
        assert!(h.get_f64("count").unwrap() >= 1.0);
        assert!(h.get_f64("p50").is_some());
    }

    #[test]
    fn http_endpoint_serves_metrics_and_404() {
        set_enabled(true);
        counter("test_http_ctr").add(9);
        let polled = Arc::new(AtomicU64::new(0));
        let p2 = Arc::clone(&polled);
        let mut srv = MetricsServer::serve(
            "127.0.0.1:0",
            Some(Arc::new(move || {
                p2.fetch_add(1, Ordering::Relaxed);
            })),
        )
        .expect("bind");
        let body = scrape(&srv.local_addr()).expect("scrape");
        assert!(body.contains("test_http_ctr 9"), "{body}");
        assert!(polled.load(Ordering::Relaxed) >= 1, "poll ran before render");
        // non-/metrics path 404s
        let mut s = TcpStream::connect(srv.local_addr()).unwrap();
        s.write_all(b"GET /nope HTTP/1.1\r\nConnection: close\r\n\r\n").unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        assert!(out.starts_with("HTTP/1.1 404"));
        srv.stop();
    }

    #[test]
    fn jsonl_exporter_appends_snapshots() {
        set_enabled(true);
        let dir = std::env::temp_dir()
            .join(format!("areal_metrics_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("metrics_live.jsonl");
        let _ = std::fs::remove_file(&path);
        counter("test_exporter_ctr").add(1);
        let mut ex = JsonlExporter::start(path.clone(), Duration::from_millis(30), None);
        std::thread::sleep(Duration::from_millis(100));
        ex.stop();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert!(lines.len() >= 2, "periodic + final snapshots: {}", lines.len());
        for l in lines {
            Json::parse(l).expect("every line is valid json");
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn bucket_geometry_is_monotone() {
        let mut last = 0usize;
        for e in -25..40 {
            let v = (e as f64).exp2();
            let b = bucket_of(v);
            assert!(b >= last, "bucket index monotone in value");
            last = b;
        }
        assert_eq!(bucket_of(0.0), 0);
        assert_eq!(bucket_of(-1.0), 0);
        assert_eq!(bucket_of(f64::MAX), NB - 1);
        for i in 0..NB - 1 {
            assert!(bucket_lo(i) < bucket_lo(i + 1));
        }
    }
}
