//! Poison-recovering lock helpers — the project-wide answer to the
//! panic-path policy enforced by `areal-lint` (DESIGN.md §12).
//!
//! `Mutex::lock().unwrap()` turns one panicked writer into a cascade:
//! every later thread that touches the lock dies on the poison flag even
//! though the protected data is still structurally sound (every guarded
//! region in this codebase either finishes its mutation or panics before
//! starting it). The helpers below recover the inner guard instead, so a
//! crashed rollout worker degrades to *its* replica being retired rather
//! than poisoning the router, the trace ring, or the metrics registry for
//! everyone else.
//!
//! Naming: `plock`/`pread`/`pwrite` ("poison-tolerant lock/read/write")
//! are what `areal-lint`'s lock-order pass recognises as acquisitions, so
//! converted call sites stay visible to the static analysis.

use std::sync::{
    Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard,
    WaitTimeoutResult,
};
use std::time::Duration;

/// Poison-tolerant `Mutex` access.
pub trait MutexExt<T> {
    /// Like [`Mutex::lock`], but recovers the guard from a poisoned lock
    /// instead of panicking the caller.
    fn plock(&self) -> MutexGuard<'_, T>;
}

impl<T> MutexExt<T> for Mutex<T> {
    fn plock(&self) -> MutexGuard<'_, T> {
        self.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// Poison-tolerant `RwLock` access.
pub trait RwLockExt<T> {
    /// Like [`RwLock::read`], recovering from poison.
    fn pread(&self) -> RwLockReadGuard<'_, T>;
    /// Like [`RwLock::write`], recovering from poison.
    fn pwrite(&self) -> RwLockWriteGuard<'_, T>;
}

impl<T> RwLockExt<T> for RwLock<T> {
    fn pread(&self) -> RwLockReadGuard<'_, T> {
        self.read().unwrap_or_else(|e| e.into_inner())
    }

    fn pwrite(&self) -> RwLockWriteGuard<'_, T> {
        self.write().unwrap_or_else(|e| e.into_inner())
    }
}

/// Poison-tolerant `Condvar` waits (the guard re-acquisition after a wait
/// carries the same poison flag as a direct `lock()`).
pub trait CondvarExt {
    fn pwait<'a, T>(&self, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T>;
    fn pwait_timeout<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
        dur: Duration,
    ) -> (MutexGuard<'a, T>, WaitTimeoutResult);
}

impl CondvarExt for Condvar {
    fn pwait<'a, T>(&self, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
        self.wait(guard).unwrap_or_else(|e| e.into_inner())
    }

    fn pwait_timeout<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
        dur: Duration,
    ) -> (MutexGuard<'a, T>, WaitTimeoutResult) {
        self.wait_timeout(guard, dur)
            .unwrap_or_else(|e| e.into_inner())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn plock_recovers_from_poison() {
        let m = Arc::new(Mutex::new(7usize));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.lock().is_err(), "lock really is poisoned");
        assert_eq!(*m.plock(), 7, "plock recovers the data");
    }

    #[test]
    fn pread_pwrite_recover_from_poison() {
        let l = Arc::new(RwLock::new(3usize));
        let l2 = Arc::clone(&l);
        let _ = std::thread::spawn(move || {
            let _g = l2.write().unwrap();
            panic!("poison it");
        })
        .join();
        assert_eq!(*l.pread(), 3);
        *l.pwrite() = 4;
        assert_eq!(*l.pread(), 4);
    }

    #[test]
    fn pwait_timeout_times_out() {
        let m = Mutex::new(());
        let cv = Condvar::new();
        let g = m.plock();
        let (_g, res) = cv.pwait_timeout(g, Duration::from_millis(1));
        assert!(res.timed_out());
    }
}
