//! Minimal benchmark harness (criterion is not in the offline vendor set).
//!
//! `cargo bench` targets use `harness = false` and call into this module.
//! Reports mean / std / p50 / p95 wall-clock per iteration after a warmup
//! phase, criterion-style, plus a throughput row when an item count is
//! given. Results can also be appended to a CSV for EXPERIMENTS.md.

use std::time::{Duration, Instant};

use super::stats;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub throughput: Option<f64>,
}

impl BenchResult {
    pub fn report(&self) {
        let fmt_t = |s: f64| {
            if s < 1e-6 {
                format!("{:8.1} ns", s * 1e9)
            } else if s < 1e-3 {
                format!("{:8.2} µs", s * 1e6)
            } else if s < 1.0 {
                format!("{:8.3} ms", s * 1e3)
            } else {
                format!("{:8.3} s ", s)
            }
        };
        let tp = match self.throughput {
            Some(t) if t >= 1e6 => format!("  {:10.2} Mitem/s", t / 1e6),
            Some(t) if t >= 1e3 => format!("  {:10.2} Kitem/s", t / 1e3),
            Some(t) => format!("  {t:10.2} item/s"),
            None => String::new(),
        };
        println!(
            "{:<44} {} ±{} p50 {} p95 {} ({} iters){}",
            self.name,
            fmt_t(self.mean_s),
            fmt_t(self.std_s),
            fmt_t(self.p50_s),
            fmt_t(self.p95_s),
            self.iters,
            tp
        );
    }
}

pub struct Bench {
    /// target measurement time (default 2 s, override with AREAL_BENCH_SECS)
    pub measure: Duration,
    pub warmup: Duration,
    pub max_iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        let secs = std::env::var("AREAL_BENCH_SECS")
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .unwrap_or(2.0);
        Self {
            measure: Duration::from_secs_f64(secs),
            warmup: Duration::from_secs_f64((secs / 4.0).min(1.0)),
            max_iters: 100_000,
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Self {
            measure: Duration::from_millis(300),
            warmup: Duration::from_millis(50),
            max_iters: 10_000,
        }
    }

    /// Time `f` repeatedly; `f` should perform one full iteration.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        // warmup
        let w0 = Instant::now();
        let mut warm_iters = 0usize;
        while w0.elapsed() < self.warmup && warm_iters < self.max_iters {
            f();
            warm_iters += 1;
        }
        // measure
        let mut samples = Vec::new();
        let m0 = Instant::now();
        while m0.elapsed() < self.measure && samples.len() < self.max_iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        if samples.is_empty() {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        BenchResult {
            name: name.to_string(),
            iters: samples.len(),
            mean_s: stats::mean(&samples),
            std_s: stats::std(&samples),
            p50_s: stats::percentile(&samples, 50.0),
            p95_s: stats::percentile(&samples, 95.0),
            throughput: None,
        }
    }

    /// Like `run` but reports items/second given `items` per iteration.
    pub fn run_throughput<F: FnMut()>(&self, name: &str, items: f64, f: F)
        -> BenchResult {
        let mut r = self.run(name, f);
        r.throughput = Some(items / r.mean_s);
        r
    }
}

/// Prevent the optimizer from eliding a value (ptr read volatile trick).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bench::quick();
        let r = b.run("noop-ish", || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.iters > 0);
        assert!(r.mean_s >= 0.0);
        assert!(r.p95_s >= r.p50_s);
    }

    #[test]
    fn throughput_positive() {
        let b = Bench::quick();
        let r = b.run_throughput("tp", 1000.0, || {
            black_box((0..1000).sum::<u64>());
        });
        assert!(r.throughput.unwrap() > 0.0);
    }
}
