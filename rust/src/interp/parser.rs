//! Recursive-descent parser + checked evaluator.

use super::lexer::{lex, Token};

#[derive(Debug, Clone, PartialEq)]
pub enum Ast {
    Num(i64),
    Neg(Box<Ast>),
    Add(Box<Ast>, Box<Ast>),
    Sub(Box<Ast>, Box<Ast>),
    Mul(Box<Ast>, Box<Ast>),
    Div(Box<Ast>, Box<Ast>),
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EvalError {
    Lex(usize),
    Parse(String),
    DivZero,
    NonIntegerDiv,
    Overflow,
    TooDeep,
}

impl std::fmt::Display for EvalError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EvalError::Lex(pos) => write!(f, "lex error at byte {pos}"),
            EvalError::Parse(msg) => write!(f, "parse error: {msg}"),
            EvalError::DivZero => write!(f, "division by zero"),
            EvalError::NonIntegerDiv => write!(f, "non-integer division"),
            EvalError::Overflow => write!(f, "arithmetic overflow"),
            EvalError::TooDeep => write!(f, "expression too deep"),
        }
    }
}

impl std::error::Error for EvalError {}

const MAX_DEPTH: usize = 64;

struct P<'a> {
    toks: &'a [Token],
    pos: usize,
}

impl<'a> P<'a> {
    fn peek(&self) -> Option<Token> {
        self.toks.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<Token> {
        let t = self.peek()?;
        self.pos += 1;
        Some(t)
    }

    fn expr(&mut self, depth: usize) -> Result<Ast, EvalError> {
        if depth > MAX_DEPTH {
            return Err(EvalError::TooDeep);
        }
        let mut lhs = self.term(depth + 1)?;
        loop {
            match self.peek() {
                Some(Token::Plus) => {
                    self.pos += 1;
                    let rhs = self.term(depth + 1)?;
                    lhs = Ast::Add(Box::new(lhs), Box::new(rhs));
                }
                Some(Token::Minus) => {
                    self.pos += 1;
                    let rhs = self.term(depth + 1)?;
                    lhs = Ast::Sub(Box::new(lhs), Box::new(rhs));
                }
                _ => return Ok(lhs),
            }
        }
    }

    fn term(&mut self, depth: usize) -> Result<Ast, EvalError> {
        if depth > MAX_DEPTH {
            return Err(EvalError::TooDeep);
        }
        let mut lhs = self.factor(depth + 1)?;
        loop {
            match self.peek() {
                Some(Token::Star) => {
                    self.pos += 1;
                    let rhs = self.factor(depth + 1)?;
                    lhs = Ast::Mul(Box::new(lhs), Box::new(rhs));
                }
                Some(Token::Slash) => {
                    self.pos += 1;
                    let rhs = self.factor(depth + 1)?;
                    lhs = Ast::Div(Box::new(lhs), Box::new(rhs));
                }
                _ => return Ok(lhs),
            }
        }
    }

    fn factor(&mut self, depth: usize) -> Result<Ast, EvalError> {
        if depth > MAX_DEPTH {
            return Err(EvalError::TooDeep);
        }
        match self.bump() {
            Some(Token::Num(n)) => Ok(Ast::Num(n)),
            Some(Token::Minus) => Ok(Ast::Neg(Box::new(self.factor(depth + 1)?))),
            Some(Token::LParen) => {
                let e = self.expr(depth + 1)?;
                match self.bump() {
                    Some(Token::RParen) => Ok(e),
                    _ => Err(EvalError::Parse("missing ')'".into())),
                }
            }
            t => Err(EvalError::Parse(format!("unexpected token {t:?}"))),
        }
    }
}

/// Parse an expression string into an AST.
pub fn parse(s: &str) -> Result<Ast, EvalError> {
    let toks = lex(s).map_err(EvalError::Lex)?;
    if toks.is_empty() {
        return Err(EvalError::Parse("empty expression".into()));
    }
    let mut p = P { toks: &toks, pos: 0 };
    let ast = p.expr(0)?;
    if p.pos != toks.len() {
        return Err(EvalError::Parse(format!("trailing tokens at {}", p.pos)));
    }
    Ok(ast)
}

fn eval_ast(ast: &Ast) -> Result<i64, EvalError> {
    match ast {
        Ast::Num(n) => Ok(*n),
        Ast::Neg(a) => eval_ast(a)?.checked_neg().ok_or(EvalError::Overflow),
        Ast::Add(a, b) => eval_ast(a)?
            .checked_add(eval_ast(b)?)
            .ok_or(EvalError::Overflow),
        Ast::Sub(a, b) => eval_ast(a)?
            .checked_sub(eval_ast(b)?)
            .ok_or(EvalError::Overflow),
        Ast::Mul(a, b) => eval_ast(a)?
            .checked_mul(eval_ast(b)?)
            .ok_or(EvalError::Overflow),
        Ast::Div(a, b) => {
            let (a, b) = (eval_ast(a)?, eval_ast(b)?);
            if b == 0 {
                Err(EvalError::DivZero)
            } else if a % b != 0 {
                // countdown-style puzzles require exact division
                Err(EvalError::NonIntegerDiv)
            } else {
                Ok(a / b)
            }
        }
    }
}

/// Parse and evaluate.
pub fn eval(s: &str) -> Result<i64, EvalError> {
    eval_ast(&parse(s)?)
}

/// Collect the number literals of an AST in order of appearance.
fn literals(ast: &Ast, out: &mut Vec<i64>) {
    match ast {
        Ast::Num(n) => out.push(*n),
        Ast::Neg(a) => literals(a, out),
        Ast::Add(a, b) | Ast::Sub(a, b) | Ast::Mul(a, b) | Ast::Div(a, b) => {
            literals(a, out);
            literals(b, out);
        }
    }
}

/// Evaluate and also check the multiset of number literals used is a
/// sub-multiset of `allowed` (the countdown rule: each given number at most
/// once). Returns (value, numbers_ok).
pub fn eval_with_numbers(s: &str, allowed: &[i64]) -> Result<(i64, bool), EvalError> {
    let ast = parse(s)?;
    let v = eval_ast(&ast)?;
    let mut used = Vec::new();
    literals(&ast, &mut used);
    let mut pool = allowed.to_vec();
    let ok = used.iter().all(|u| {
        if let Some(i) = pool.iter().position(|p| p == u) {
            pool.swap_remove(i);
            true
        } else {
            false
        }
    });
    Ok((v, ok))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    #[test]
    fn precedence() {
        assert_eq!(eval("2+3*4").unwrap(), 14);
        assert_eq!(eval("(2+3)*4").unwrap(), 20);
        assert_eq!(eval("2-3-4").unwrap(), -5); // left assoc
        assert_eq!(eval("12/3/2").unwrap(), 2);
    }

    #[test]
    fn unary_minus() {
        assert_eq!(eval("-3+5").unwrap(), 2);
        assert_eq!(eval("4*-2").unwrap(), -8);
        assert_eq!(eval("--7").unwrap(), 7);
    }

    #[test]
    fn division_rules() {
        assert_eq!(eval("6/3").unwrap(), 2);
        assert_eq!(eval("7/3"), Err(EvalError::NonIntegerDiv));
        assert_eq!(eval("7/0"), Err(EvalError::DivZero));
    }

    #[test]
    fn overflow_checked() {
        assert_eq!(eval("999999999999*999999999999"), Err(EvalError::Overflow));
    }

    #[test]
    fn parse_errors() {
        assert!(matches!(eval("1+"), Err(EvalError::Parse(_))));
        assert!(matches!(eval("(1+2"), Err(EvalError::Parse(_))));
        assert!(matches!(eval("1 2"), Err(EvalError::Parse(_))));
        assert!(matches!(eval(""), Err(EvalError::Parse(_))));
        assert!(matches!(eval("1+a"), Err(EvalError::Lex(_))));
    }

    #[test]
    fn number_usage_check() {
        let (v, ok) = eval_with_numbers("3*7-2", &[3, 7, 2]).unwrap();
        assert_eq!((v, ok), (19, true));
        // reuses 3 twice but only one 3 allowed
        let (_, ok) = eval_with_numbers("3*3", &[3, 7]).unwrap();
        assert!(!ok);
        // uses a number that was never given
        let (_, ok) = eval_with_numbers("5+1", &[5, 2]).unwrap();
        assert!(!ok);
        // duplicates allowed when given twice
        let (_, ok) = eval_with_numbers("3+3", &[3, 3]).unwrap();
        assert!(ok);
    }

    #[test]
    fn prop_random_flat_expressions_evaluate() {
        // property: expressions built from known-good pieces always evaluate
        // and match a direct fold
        prop_check(200, |rng| {
            let n = rng.range_usize(1, 6);
            let mut s = String::new();
            let mut expect: i64 = 0;
            let mut sign = 1i64;
            for i in 0..n {
                let x = rng.range_i64(0, 99);
                if i > 0 {
                    if rng.chance(0.5) {
                        s.push('+');
                        sign = 1;
                    } else {
                        s.push('-');
                        sign = -1;
                    }
                }
                s.push_str(&x.to_string());
                expect += sign * x;
            }
            let got = eval(&s).map_err(|e| format!("{s}: {e}"))?;
            crate::prop_assert!(got == expect, "{s}: got {got}, want {expect}");
            Ok(())
        });
    }

    #[test]
    fn prop_parser_never_panics_on_ascii_junk() {
        prop_check(300, |rng| {
            let len = rng.range_usize(0, 12);
            let charset: Vec<char> = "0123456789+-*/() ".chars().collect();
            let s: String = (0..len)
                .map(|_| charset[rng.range_usize(0, charset.len() - 1)])
                .collect();
            let _ = eval(&s); // must not panic; errors are fine
            Ok(())
        });
    }
}
