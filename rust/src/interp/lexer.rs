//! Expression lexer.

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Token {
    Num(i64),
    Plus,
    Minus,
    Star,
    Slash,
    LParen,
    RParen,
}

/// Tokenize an expression string. Whitespace is skipped; any other
/// character is an error (returned as its position).
pub fn lex(s: &str) -> Result<Vec<Token>, usize> {
    let b = s.as_bytes();
    let mut out = Vec::new();
    let mut i = 0;
    while i < b.len() {
        match b[i] {
            b' ' | b'\t' => i += 1,
            b'+' => {
                out.push(Token::Plus);
                i += 1;
            }
            b'-' => {
                out.push(Token::Minus);
                i += 1;
            }
            b'*' => {
                out.push(Token::Star);
                i += 1;
            }
            b'/' => {
                out.push(Token::Slash);
                i += 1;
            }
            b'(' => {
                out.push(Token::LParen);
                i += 1;
            }
            b')' => {
                out.push(Token::RParen);
                i += 1;
            }
            c if c.is_ascii_digit() => {
                let start = i;
                while i < b.len() && b[i].is_ascii_digit() {
                    i += 1;
                }
                // bounded numbers: reject absurd literals early
                if i - start > 12 {
                    return Err(start);
                }
                let n: i64 = s[start..i].parse().map_err(|_| start)?;
                out.push(Token::Num(n));
            }
            _ => return Err(i),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lexes_expression() {
        assert_eq!(
            lex("12+3*(4-5)").unwrap(),
            vec![
                Token::Num(12),
                Token::Plus,
                Token::Num(3),
                Token::Star,
                Token::LParen,
                Token::Num(4),
                Token::Minus,
                Token::Num(5),
                Token::RParen
            ]
        );
    }

    #[test]
    fn skips_whitespace() {
        assert_eq!(lex("  7 ").unwrap(), vec![Token::Num(7)]);
    }

    #[test]
    fn rejects_garbage() {
        assert_eq!(lex("1+x"), Err(2));
    }

    #[test]
    fn rejects_huge_literal() {
        assert!(lex("9999999999999999999").is_err());
    }

    #[test]
    fn empty_ok() {
        assert_eq!(lex("").unwrap(), vec![]);
    }
}
