//! Arithmetic expression interpreter — the from-scratch substrate behind the
//! code-like task's reward: the paper's coding reward service "extracts the
//! code and executes unit tests"; here the model emits an arithmetic
//! expression program, and this interpreter executes it against the task's
//! expected value (the unit test).
//!
//! Grammar (integer arithmetic, i64, checked):
//!     expr   := term (('+' | '-') term)*
//!     term   := factor (('*' | '/') factor)*
//!     factor := NUMBER | '-' factor | '(' expr ')'

pub mod lexer;
pub mod parser;

pub use lexer::{lex, Token};
pub use parser::{eval, eval_with_numbers, parse, Ast, EvalError};
