//! Live rebalancing acceptance (ISSUE 5): drive a drifting workload
//! through the real control plane — `run_controller` submitting GRPO
//! groups under the Eq. 3 gate, the real `run_rebalancer` thread watching
//! headroom/backlog, and workers executing conversions through the
//! `RoleBoard` exactly as `rollout::serve_loop` does (retire at idle via
//! the epoch-fenced salvage path, park, rejoin through `add_replica`).
//! The workers here serve their inboxes with a mock "engine" (recording
//! served requests instead of decoding — the real engine needs AOT
//! artifacts), but every router/board/gate/trace interaction is the
//! production code path.
//!
//! Acceptance: at least one gen→train and one train→gen conversion occurs
//! (observed via `Event::Rebalance`), zero requests are lost and no GRPO
//! group is left partial across the conversions.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use areal::coordinator::controller::{run_controller, ControllerCfg};
use areal::coordinator::rebalance::{run_rebalancer, RebalanceCfg, RoleBoard};
use areal::coordinator::{Event, GenRouter, ParamServer, StalenessGate, Trace};
use areal::runtime::executor::SendLiteral;
use areal::runtime::{HostTensor, ParamSet};
use areal::serve::{Control, RoutePolicy, RouterCfg};
use areal::tasks::dataset::LevelMix;
use areal::tasks::{AdditionTask, Dataset};

const GROUP: usize = 4;
const BATCH: usize = 8;
const BUDGET: u64 = 160; // 40 whole groups

fn pset(v: u64) -> Arc<ParamSet> {
    let lit = HostTensor::scalar_f32(0.0).to_literal().unwrap();
    ParamSet::with_version(vec![SendLiteral(lit)], v)
}

fn wait_until(what: &str, mut cond: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(20);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for: {what}");
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// A rollout worker reduced to its dispatch-plane contract: serve the
/// epoch-fenced inbox, honor Drain, and at idle offer the replica to the
/// rebalancer (`try_retire` → park → `try_rejoin`) — the exact
/// conversion protocol of `rollout::serve_loop` +
/// `run_supervised_rollout_worker`.
#[allow(clippy::too_many_arguments)]
fn mock_worker(w: usize, router: Arc<GenRouter>, board: Arc<RoleBoard>,
               trace: Arc<Trace>, stop: Arc<AtomicBool>, draining: Arc<AtomicBool>,
               served: Arc<Mutex<HashMap<u64, usize>>>, slow_ms: Arc<AtomicU64>) {
    let mut slot = w;
    'serve: loop {
        let epoch = router.epoch(slot);
        loop {
            if stop.load(Ordering::Acquire) {
                return;
            }
            if router
                .take_control_at(slot, epoch)
                .iter()
                .any(|c| *c == Control::Drain)
            {
                return;
            }
            let p = router.pull_at(slot, epoch, GROUP);
            if p.reqs.is_empty() {
                if !draining.load(Ordering::Acquire)
                    && board.try_retire(router.as_ref(), slot, epoch, &trace)
                {
                    // train role: park until rejoined or shut down
                    loop {
                        if stop.load(Ordering::Acquire)
                            || draining.load(Ordering::Acquire)
                        {
                            return;
                        }
                        if let Some((s, _epoch)) =
                            board.try_rejoin(router.as_ref(), &trace)
                        {
                            slot = s;
                            continue 'serve;
                        }
                        std::thread::sleep(Duration::from_millis(1));
                    }
                }
                std::thread::sleep(Duration::from_millis(1));
                continue;
            }
            for q in p.reqs {
                let ms = slow_ms.load(Ordering::Acquire);
                if ms > 0 {
                    std::thread::sleep(Duration::from_millis(ms));
                }
                *served.lock().unwrap().entry(q.group).or_default() += 1;
                router.complete(slot, q.tokens.len());
            }
        }
    }
}

#[test]
fn rebalancer_converts_both_ways_with_no_lost_requests() {
    let router: Arc<GenRouter> =
        Arc::new(GenRouter::new(3, RouterCfg::new(RoutePolicy::Affinity, 8, 0)));
    let gate = Arc::new(StalenessGate::new(BATCH, Some(1)));
    let server = ParamServer::new(pset(0));
    let board = Arc::new(RoleBoard::new(1, 3, 3));
    let trace = Arc::new(Trace::new(true));
    let stop = Arc::new(AtomicBool::new(false));
    let draining = Arc::new(AtomicBool::new(false));
    let served: Arc<Mutex<HashMap<u64, usize>>> = Arc::new(Mutex::new(HashMap::new()));
    let slow_ms = Arc::new(AtomicU64::new(0));

    // the real controller thread: tokenize once, atomic whole-group
    // reservation against the gate, router submission
    let controller = {
        let ds = Dataset::new(Arc::new(AdditionTask), 1, LevelMix::single(1));
        let (gate, server, router, stop, trace) = (
            Arc::clone(&gate),
            Arc::clone(&server),
            Arc::clone(&router),
            Arc::clone(&stop),
            Arc::clone(&trace),
        );
        std::thread::Builder::new()
            .name("controller".into())
            .spawn(move || {
                run_controller(
                    ds, gate, server, router, stop,
                    ControllerCfg { group_size: GROUP, max_submissions: Some(BUDGET) },
                    trace,
                )
            })
            .unwrap()
    };

    // the real rebalancer thread, on a fast observation interval
    let rebalancer = {
        let (gate, server, router, board, stop, draining) = (
            Arc::clone(&gate),
            Arc::clone(&server),
            Arc::clone(&router),
            Arc::clone(&board),
            Arc::clone(&stop),
            Arc::clone(&draining),
        );
        std::thread::Builder::new()
            .name("rebalancer".into())
            .spawn(move || {
                run_rebalancer(gate, server, router, board, stop, draining,
                               RebalanceCfg::new(1, 3, 1.0),
                               Duration::from_millis(5), GROUP)
            })
            .unwrap()
    };

    let workers: Vec<_> = (0..3)
        .map(|w| {
            let (router, board, trace, stop, draining, served, slow_ms) = (
                Arc::clone(&router),
                Arc::clone(&board),
                Arc::clone(&trace),
                Arc::clone(&stop),
                Arc::clone(&draining),
                Arc::clone(&served),
                Arc::clone(&slow_ms),
            );
            std::thread::Builder::new()
                .name(format!("rollout-{w}"))
                .spawn(move || {
                    mock_worker(w, router, board, trace, stop, draining, served,
                                slow_ms)
                })
                .unwrap()
        })
        .collect();

    // --- phase 1: the trainer "stalls" at version 0. Eq. 3 admits
    // exactly B·(η+1) = 16 submissions, the fleet drains them fast, the
    // headroom pins at zero with shallow inboxes — the rebalancer must
    // shed generation capacity down to min_gen through idle retirements.
    wait_until("phase-1 submissions gated at 16", || gate.submitted() == 16);
    wait_until("gen fleet shed to min_gen", || router.n_alive() == 1);
    let to_train_p1 = trace.count(|e| {
        matches!(e, Event::Rebalance { from: "gen", to: "train", .. })
    });
    assert!(to_train_p1 >= 2, "expected >= 2 gen->train conversions, got {to_train_p1}");

    // --- phase 2: the "trainer" leaps ahead (version 50 opens ~50
    // batches of headroom) while serving turns slow — deep inboxes on an
    // open gate are the generation-bound signal, and the rebalancer must
    // bring parked capacity back.
    slow_ms.store(25, Ordering::Release);
    server.publish(pset(50));
    wait_until("a parked worker rejoined generation", || {
        trace.count(|e| matches!(e, Event::Rebalance { from: "train", to: "gen", .. }))
            >= 1
    });

    // --- run to quiescence: full submission budget served, nothing lost
    slow_ms.store(0, Ordering::Release);
    wait_until("all 160 submissions served", || {
        served.lock().unwrap().values().sum::<usize>() as u64 == BUDGET
    });
    assert_eq!(gate.submitted(), BUDGET, "controller stopped at its budget");
    assert_eq!(router.queued_total(), 0, "nothing stranded in any inbox");

    // --- shutdown: the drain_and_join discipline
    draining.store(true, Ordering::Release);
    rebalancer.join().unwrap();
    router.broadcast(Control::Drain);
    for h in workers {
        h.join().unwrap();
    }
    stop.store(true, Ordering::Release);
    controller.join().unwrap();

    // zero lost requests and no partial GRPO group across conversions:
    // every one of the 40 groups was served exactly G=4 times
    let served = served.lock().unwrap();
    assert_eq!(served.len(), 40, "all 40 groups reached the fleet");
    for (gid, n) in served.iter() {
        assert_eq!(*n, GROUP, "group {gid} served {n} != {GROUP} siblings");
    }
    let to_train = trace
        .count(|e| matches!(e, Event::Rebalance { from: "gen", to: "train", .. }));
    let to_gen = trace
        .count(|e| matches!(e, Event::Rebalance { from: "train", to: "gen", .. }));
    assert!(to_train >= 2 && to_gen >= 1,
            "conversions: {to_train} gen->train, {to_gen} train->gen");
    // conversions are clean role changes, not failures
    assert_eq!(trace.count(|e| matches!(e, Event::ReplicaDown { .. })), 0);
}
