//! Property tests for the serve/ subsystem (via util::prop): block-manager
//! and radix-tree invariants under random operation sequences.
//!
//! The three invariants the ISSUE pins down:
//! - ref-counts never go negative (enforced structurally: release on a free
//!   block panics; the shadow-model test proves counts stay exact);
//! - eviction never frees a block an in-flight sequence still references;
//! - insert-then-match returns the longest cached prefix (the block-aligned
//!   prefix of what was inserted).
//!
//! Plus the weight-shard frame codec (ISSUE 10): chunking/reassembly
//! round-trips at arbitrary chunk sizes (including the exact-divisible ±1
//! boundaries), duplicated offers are idempotent, and version tags stay
//! monotone under interleaved streams.

use std::collections::HashMap;

use areal::prop_assert;
use areal::serve::{
    chunk_count, chunk_slice, hex_decode, hex_encode, BlockId, BlockManager, RadixCache,
    Scheduler, SeqId, ServeCfg, WeightAssembler,
};
use areal::util::prop::prop_check;
use areal::util::rng::Rng;

fn random_tokens(rng: &mut Rng, len: usize) -> Vec<i32> {
    (0..len).map(|_| rng.range_i64(3, 47) as i32).collect()
}

#[test]
fn block_manager_refcounts_match_shadow_model() {
    prop_check(300, |rng| {
        let num_blocks = rng.range_usize(1, 24);
        let mut bm = BlockManager::new(num_blocks, rng.range_usize(1, 16));
        // our handles: block id -> references we hold (we are the only user,
        // so this must equal the manager's refcount exactly)
        let mut held: HashMap<BlockId, u32> = HashMap::new();
        for _ in 0..rng.range_usize(0, 120) {
            let ids: Vec<BlockId> = held.keys().copied().collect();
            match rng.range_usize(0, 3) {
                0 => {
                    if let Some(id) = bm.try_alloc(rng.range_i64(0, 4) as u64) {
                        prop_assert!(
                            !held.contains_key(&id),
                            "alloc handed out a block we already hold"
                        );
                        held.insert(id, 1);
                    } else {
                        prop_assert!(
                            bm.free_blocks() == 0,
                            "alloc failed with {} free blocks",
                            bm.free_blocks()
                        );
                    }
                }
                1 => {
                    if let Some(&id) = ids.first() {
                        bm.retain(id);
                        *held.get_mut(&id).unwrap() += 1;
                    }
                }
                2 => {
                    if let Some(&id) = ids.last() {
                        bm.release(id);
                        let c = held.get_mut(&id).unwrap();
                        *c -= 1;
                        if *c == 0 {
                            held.remove(&id);
                        }
                    }
                }
                _ => {
                    if let Some(&id) = ids.first() {
                        let before = *held.get(&id).unwrap();
                        if let Some(nid) = bm.make_writable(id, 9) {
                            if nid == id {
                                prop_assert!(before == 1, "COW skipped a shared block");
                            } else {
                                // one of our references moved to the copy
                                let c = held.get_mut(&id).unwrap();
                                *c -= 1;
                                if *c == 0 {
                                    held.remove(&id);
                                }
                                held.insert(nid, 1);
                            }
                        }
                    }
                }
            }
            if let Err(e) = bm.check() {
                return Err(e);
            }
            for (&id, &c) in &held {
                prop_assert!(
                    bm.ref_count(id) == c,
                    "block {id}: manager says {} refs, model says {c}",
                    bm.ref_count(id)
                );
            }
        }
        Ok(())
    });
}

#[test]
fn eviction_never_frees_a_referenced_block() {
    prop_check(200, |rng| {
        let bs = rng.range_usize(2, 6);
        let mut bm = BlockManager::new(rng.range_usize(8, 48), bs);
        let mut cache = RadixCache::new();
        // block id -> references WE hold (from match_prefix)
        let mut held: HashMap<BlockId, u32> = HashMap::new();
        let mut inserted: Vec<Vec<i32>> = Vec::new();
        for _ in 0..rng.range_usize(1, 60) {
            match rng.range_usize(0, 3) {
                0 => {
                    let t = random_tokens(rng, rng.range_usize(0, 4 * bs + 2));
                    cache.insert(&t, 0, None, &mut bm);
                    inserted.push(t);
                }
                1 => {
                    if let Some(t) = inserted.last() {
                        let m = cache.match_prefix(t, 0, &mut bm);
                        for b in m.blocks {
                            *held.entry(b).or_insert(0) += 1;
                        }
                    }
                }
                2 => {
                    cache.evict(rng.range_usize(1, 8), &mut bm);
                }
                _ => {
                    // release one of our held references
                    if let Some(&id) = held.keys().next() {
                        bm.release(id);
                        let c = held.get_mut(&id).unwrap();
                        *c -= 1;
                        if *c == 0 {
                            held.remove(&id);
                        }
                    }
                }
            }
            if let Err(e) = bm.check() {
                return Err(e);
            }
            if let Err(e) = cache.check(&bm) {
                return Err(e);
            }
            // THE invariant: every block an in-flight user still references
            // is alive, no matter what eviction did
            for (&id, &c) in &held {
                prop_assert!(
                    bm.ref_count(id) >= c,
                    "evicted block {id} out from under {c} live references"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn insert_then_match_returns_longest_cached_prefix() {
    prop_check(300, |rng| {
        let bs = rng.range_usize(1, 8);
        let mut bm = BlockManager::new(64, bs);
        let mut cache = RadixCache::new();
        let len = rng.range_usize(0, 40);
        let t = random_tokens(rng, len);
        cache.insert(&t, 0, None, &mut bm);
        let full = len / bs * bs;

        // exact query: the whole block-aligned prefix
        let m = cache.match_prefix(&t, 0, &mut bm);
        prop_assert!(
            m.tokens == full,
            "inserted {len} tokens (bs {bs}), matched {} != {full}",
            m.tokens
        );
        prop_assert!(m.blocks.len() == full / bs.max(1), "block count mismatch");
        for &b in &m.blocks {
            bm.release(b);
        }

        // shorter query: its own block-aligned length
        let cut = rng.range_usize(0, len);
        let m = cache.match_prefix(&t[..cut], 0, &mut bm);
        prop_assert!(
            m.tokens == cut / bs * bs,
            "prefix query of {cut} matched {}",
            m.tokens
        );
        for &b in &m.blocks {
            bm.release(b);
        }

        // extended query: still the inserted prefix (an extension may match
        // at most what is cached)
        let mut ext = t.clone();
        ext.extend(random_tokens(rng, bs));
        let m = cache.match_prefix(&ext, 0, &mut bm);
        prop_assert!(
            m.tokens == full,
            "extension query matched {} != {full}",
            m.tokens
        );
        for &b in &m.blocks {
            bm.release(b);
        }

        if let Err(e) = cache.check(&bm) {
            return Err(e);
        }
        Ok(())
    });
}

fn random_bytes(rng: &mut Rng, len: usize) -> Vec<u8> {
    (0..len).map(|_| rng.range_i64(0, 256) as u8).collect()
}

#[test]
fn weight_chunking_round_trips_at_any_chunk_size() {
    prop_check(300, |rng| {
        let cb = rng.range_usize(1, 40);
        // exercise the exact-divisible boundary and its neighbors: blob
        // lengths k*cb - 1, k*cb, k*cb + 1 (clamped at 0), plus random
        let len = match rng.range_usize(0, 4) {
            0 => rng.range_usize(0, 5) * cb,
            1 => (rng.range_usize(1, 5) * cb).saturating_sub(1),
            2 => rng.range_usize(0, 5) * cb + 1,
            _ => rng.range_usize(0, 4 * cb + 2),
        };
        let blob = random_bytes(rng, len);
        let total = chunk_count(blob.len(), cb);
        prop_assert!(total >= 1, "even an empty blob streams as one frame");
        prop_assert!(
            total == len.max(1).div_ceil(cb),
            "chunk_count({len}, {cb}) = {total}"
        );

        // every in-range index yields a slice, one past the end yields none
        let mut glued: Vec<u8> = Vec::new();
        for i in 0..total {
            let Some(s) = chunk_slice(&blob, cb, i) else {
                return Err(format!("chunk {i}/{total} missing for len {len} cb {cb}"));
            };
            prop_assert!(
                i + 1 == total || s.len() == cb,
                "only the final chunk may be short (chunk {i} has {} bytes)",
                s.len()
            );
            glued.extend_from_slice(s);
        }
        prop_assert!(
            chunk_slice(&blob, cb, total).is_none(),
            "index {total} is out of range"
        );
        prop_assert!(glued == blob, "reassembly must be bitwise round-trip");

        // the assembler agrees, even when every chunk is offered twice
        let v = rng.range_i64(1, 1 << 20) as u64;
        let mut asm = WeightAssembler::new();
        let mut done = None;
        for i in 0..total {
            let s = chunk_slice(&blob, cb, i).unwrap();
            let r = asm.offer(v, i, total, s).map_err(|e| e.to_string())?;
            if rng.chance(0.5) {
                // duplicate delivery is idempotent: dropped, not an error
                let dup = asm.offer(v, i, total, s).map_err(|e| e.to_string())?;
                prop_assert!(dup.is_none(), "duplicate chunk re-completed a stream");
            }
            done = done.or(r);
        }
        let Some((dv, dblob)) = done else {
            return Err("stream never completed".into());
        };
        prop_assert!(dv == v && dblob == blob, "assembled blob differs");
        prop_assert!(asm.done_version() == Some(v), "done_version not recorded");

        // hex transport encoding round-trips too
        let hex = hex_encode(&blob);
        prop_assert!(hex_decode(&hex).as_deref() == Some(&blob[..]), "hex round-trip");
        Ok(())
    });
}

#[test]
fn weight_assembler_versions_stay_monotone() {
    prop_check(200, |rng| {
        let cb = rng.range_usize(1, 16);
        let mut asm = WeightAssembler::new();
        let mut highest_done: Option<u64> = None;
        // a sequence of streams at random versions, some interrupted by a
        // newer publish mid-flight — the assembler must only ever complete
        // versions strictly above everything it already finished
        for _ in 0..rng.range_usize(1, 12) {
            let v = rng.range_i64(1, 64) as u64;
            // deterministic content per version: a re-drawn version must
            // stream the same bytes, as a real publisher would
            let blob: Vec<u8> = (0..(v as usize * 7) % (3 * cb + 1))
                .map(|j| (v as u8).wrapping_mul(31).wrapping_add(j as u8))
                .collect();
            let total = chunk_count(blob.len(), cb);
            let abort_at = if rng.chance(0.3) && total > 1 {
                rng.range_usize(1, total)
            } else {
                total
            };
            for i in 0..abort_at {
                let s = chunk_slice(&blob, cb, i).unwrap();
                match asm.offer(v, i, total, s) {
                    Ok(Some((dv, db))) => {
                        prop_assert!(
                            highest_done.map_or(true, |h| dv > h),
                            "completed v{dv} at or below finished v{highest_done:?}"
                        );
                        prop_assert!(dv == v && db == blob, "wrong blob for v{v}");
                        highest_done = Some(dv);
                    }
                    Ok(None) => {}
                    Err(_) => {
                        // stale-version offers may be rejected; never mid-
                        // stream of a version the assembler accepted
                        prop_assert!(
                            i == 0 || asm.progress().map_or(true, |(pv, _)| pv != v),
                            "assembler errored mid-stream of an accepted version"
                        );
                        break;
                    }
                }
            }
            if rng.chance(0.2) {
                asm.reset_partial();
                prop_assert!(asm.progress().is_none(), "reset left a partial");
            }
            prop_assert!(
                asm.done_version() == highest_done,
                "done_version diverged from the model"
            );
        }
        Ok(())
    });
}

#[test]
fn scheduler_random_walk_preserves_invariants() {
    prop_check(60, |rng| {
        let bs = rng.range_usize(2, 6);
        let cfg = ServeCfg {
            block_size: bs,
            num_blocks: rng.range_usize(16, 64),
            max_seqs: rng.range_usize(1, 4),
            prefix_cache: rng.chance(0.7),
        };
        // every sequence must individually fit the pool
        let max_len = (cfg.num_blocks * bs - bs).min(6 * bs);
        let mut s = Scheduler::new(cfg);
        let mut next_id: SeqId = 0;
        let mut active: HashMap<SeqId, Vec<i32>> = HashMap::new();
        for _ in 0..rng.range_usize(1, 80) {
            match rng.range_usize(0, 3) {
                0 => {
                    let t = random_tokens(rng, rng.range_usize(1, max_len / 2));
                    assert!(s.submit(next_id, t));
                    next_id += 1;
                }
                1 => {
                    for a in s.schedule() {
                        s.note_prefilled(a.id, &a.tokens);
                        active.insert(a.id, a.tokens);
                    }
                }
                2 => {
                    // grow one active sequence by one token
                    let Some(&id) = active.keys().next() else { continue };
                    let t = active.get_mut(&id).unwrap();
                    if t.len() >= max_len {
                        let t = active.remove(&id).unwrap();
                        s.finish(id, &t, t.len());
                        continue;
                    }
                    t.push(rng.range_i64(3, 47) as i32);
                    let new_len = t.len();
                    loop {
                        match s.grow_to(id, new_len) {
                            areal::serve::Grow::Ok => break,
                            areal::serve::Grow::Preempt(v) => {
                                let vt = active.remove(&v).unwrap();
                                s.preempt(v, &vt, vt.len());
                            }
                            areal::serve::Grow::Fail => {
                                return Err("pool cannot hold one bounded sequence".into())
                            }
                        }
                    }
                }
                _ => {
                    if let Some(&id) = active.keys().next() {
                        let t = active.remove(&id).unwrap();
                        s.finish(id, &t, t.len());
                    }
                }
            }
            if let Err(e) = s.check() {
                return Err(e);
            }
        }
        // drain: finish everything; all non-cache references must unwind
        let ids: Vec<SeqId> = active.keys().copied().collect();
        for id in ids {
            let t = active.remove(&id).unwrap();
            s.finish(id, &t, t.len());
        }
        if let Err(e) = s.check() {
            return Err(e);
        }
        Ok(())
    });
}
