//! Property tests for the serve/ subsystem (via util::prop): block-manager
//! and radix-tree invariants under random operation sequences.
//!
//! The three invariants the ISSUE pins down:
//! - ref-counts never go negative (enforced structurally: release on a free
//!   block panics; the shadow-model test proves counts stay exact);
//! - eviction never frees a block an in-flight sequence still references;
//! - insert-then-match returns the longest cached prefix (the block-aligned
//!   prefix of what was inserted).

use std::collections::HashMap;

use areal::prop_assert;
use areal::serve::{BlockId, BlockManager, RadixCache, Scheduler, SeqId, ServeCfg};
use areal::util::prop::prop_check;
use areal::util::rng::Rng;

fn random_tokens(rng: &mut Rng, len: usize) -> Vec<i32> {
    (0..len).map(|_| rng.range_i64(3, 47) as i32).collect()
}

#[test]
fn block_manager_refcounts_match_shadow_model() {
    prop_check(300, |rng| {
        let num_blocks = rng.range_usize(1, 24);
        let mut bm = BlockManager::new(num_blocks, rng.range_usize(1, 16));
        // our handles: block id -> references we hold (we are the only user,
        // so this must equal the manager's refcount exactly)
        let mut held: HashMap<BlockId, u32> = HashMap::new();
        for _ in 0..rng.range_usize(0, 120) {
            let ids: Vec<BlockId> = held.keys().copied().collect();
            match rng.range_usize(0, 3) {
                0 => {
                    if let Some(id) = bm.try_alloc(rng.range_i64(0, 4) as u64) {
                        prop_assert!(
                            !held.contains_key(&id),
                            "alloc handed out a block we already hold"
                        );
                        held.insert(id, 1);
                    } else {
                        prop_assert!(
                            bm.free_blocks() == 0,
                            "alloc failed with {} free blocks",
                            bm.free_blocks()
                        );
                    }
                }
                1 => {
                    if let Some(&id) = ids.first() {
                        bm.retain(id);
                        *held.get_mut(&id).unwrap() += 1;
                    }
                }
                2 => {
                    if let Some(&id) = ids.last() {
                        bm.release(id);
                        let c = held.get_mut(&id).unwrap();
                        *c -= 1;
                        if *c == 0 {
                            held.remove(&id);
                        }
                    }
                }
                _ => {
                    if let Some(&id) = ids.first() {
                        let before = *held.get(&id).unwrap();
                        if let Some(nid) = bm.make_writable(id, 9) {
                            if nid == id {
                                prop_assert!(before == 1, "COW skipped a shared block");
                            } else {
                                // one of our references moved to the copy
                                let c = held.get_mut(&id).unwrap();
                                *c -= 1;
                                if *c == 0 {
                                    held.remove(&id);
                                }
                                held.insert(nid, 1);
                            }
                        }
                    }
                }
            }
            if let Err(e) = bm.check() {
                return Err(e);
            }
            for (&id, &c) in &held {
                prop_assert!(
                    bm.ref_count(id) == c,
                    "block {id}: manager says {} refs, model says {c}",
                    bm.ref_count(id)
                );
            }
        }
        Ok(())
    });
}

#[test]
fn eviction_never_frees_a_referenced_block() {
    prop_check(200, |rng| {
        let bs = rng.range_usize(2, 6);
        let mut bm = BlockManager::new(rng.range_usize(8, 48), bs);
        let mut cache = RadixCache::new();
        // block id -> references WE hold (from match_prefix)
        let mut held: HashMap<BlockId, u32> = HashMap::new();
        let mut inserted: Vec<Vec<i32>> = Vec::new();
        for _ in 0..rng.range_usize(1, 60) {
            match rng.range_usize(0, 3) {
                0 => {
                    let t = random_tokens(rng, rng.range_usize(0, 4 * bs + 2));
                    cache.insert(&t, 0, None, &mut bm);
                    inserted.push(t);
                }
                1 => {
                    if let Some(t) = inserted.last() {
                        let m = cache.match_prefix(t, 0, &mut bm);
                        for b in m.blocks {
                            *held.entry(b).or_insert(0) += 1;
                        }
                    }
                }
                2 => {
                    cache.evict(rng.range_usize(1, 8), &mut bm);
                }
                _ => {
                    // release one of our held references
                    if let Some(&id) = held.keys().next() {
                        bm.release(id);
                        let c = held.get_mut(&id).unwrap();
                        *c -= 1;
                        if *c == 0 {
                            held.remove(&id);
                        }
                    }
                }
            }
            if let Err(e) = bm.check() {
                return Err(e);
            }
            if let Err(e) = cache.check(&bm) {
                return Err(e);
            }
            // THE invariant: every block an in-flight user still references
            // is alive, no matter what eviction did
            for (&id, &c) in &held {
                prop_assert!(
                    bm.ref_count(id) >= c,
                    "evicted block {id} out from under {c} live references"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn insert_then_match_returns_longest_cached_prefix() {
    prop_check(300, |rng| {
        let bs = rng.range_usize(1, 8);
        let mut bm = BlockManager::new(64, bs);
        let mut cache = RadixCache::new();
        let len = rng.range_usize(0, 40);
        let t = random_tokens(rng, len);
        cache.insert(&t, 0, None, &mut bm);
        let full = len / bs * bs;

        // exact query: the whole block-aligned prefix
        let m = cache.match_prefix(&t, 0, &mut bm);
        prop_assert!(
            m.tokens == full,
            "inserted {len} tokens (bs {bs}), matched {} != {full}",
            m.tokens
        );
        prop_assert!(m.blocks.len() == full / bs.max(1), "block count mismatch");
        for &b in &m.blocks {
            bm.release(b);
        }

        // shorter query: its own block-aligned length
        let cut = rng.range_usize(0, len);
        let m = cache.match_prefix(&t[..cut], 0, &mut bm);
        prop_assert!(
            m.tokens == cut / bs * bs,
            "prefix query of {cut} matched {}",
            m.tokens
        );
        for &b in &m.blocks {
            bm.release(b);
        }

        // extended query: still the inserted prefix (an extension may match
        // at most what is cached)
        let mut ext = t.clone();
        ext.extend(random_tokens(rng, bs));
        let m = cache.match_prefix(&ext, 0, &mut bm);
        prop_assert!(
            m.tokens == full,
            "extension query matched {} != {full}",
            m.tokens
        );
        for &b in &m.blocks {
            bm.release(b);
        }

        if let Err(e) = cache.check(&bm) {
            return Err(e);
        }
        Ok(())
    });
}

#[test]
fn scheduler_random_walk_preserves_invariants() {
    prop_check(60, |rng| {
        let bs = rng.range_usize(2, 6);
        let cfg = ServeCfg {
            block_size: bs,
            num_blocks: rng.range_usize(16, 64),
            max_seqs: rng.range_usize(1, 4),
            prefix_cache: rng.chance(0.7),
        };
        // every sequence must individually fit the pool
        let max_len = (cfg.num_blocks * bs - bs).min(6 * bs);
        let mut s = Scheduler::new(cfg);
        let mut next_id: SeqId = 0;
        let mut active: HashMap<SeqId, Vec<i32>> = HashMap::new();
        for _ in 0..rng.range_usize(1, 80) {
            match rng.range_usize(0, 3) {
                0 => {
                    let t = random_tokens(rng, rng.range_usize(1, max_len / 2));
                    assert!(s.submit(next_id, t));
                    next_id += 1;
                }
                1 => {
                    for a in s.schedule() {
                        s.note_prefilled(a.id, &a.tokens);
                        active.insert(a.id, a.tokens);
                    }
                }
                2 => {
                    // grow one active sequence by one token
                    let Some(&id) = active.keys().next() else { continue };
                    let t = active.get_mut(&id).unwrap();
                    if t.len() >= max_len {
                        let t = active.remove(&id).unwrap();
                        s.finish(id, &t, t.len());
                        continue;
                    }
                    t.push(rng.range_i64(3, 47) as i32);
                    let new_len = t.len();
                    loop {
                        match s.grow_to(id, new_len) {
                            areal::serve::Grow::Ok => break,
                            areal::serve::Grow::Preempt(v) => {
                                let vt = active.remove(&v).unwrap();
                                s.preempt(v, &vt, vt.len());
                            }
                            areal::serve::Grow::Fail => {
                                return Err("pool cannot hold one bounded sequence".into())
                            }
                        }
                    }
                }
                _ => {
                    if let Some(&id) = active.keys().next() {
                        let t = active.remove(&id).unwrap();
                        s.finish(id, &t, t.len());
                    }
                }
            }
            if let Err(e) = s.check() {
                return Err(e);
            }
        }
        // drain: finish everything; all non-cache references must unwind
        let ids: Vec<SeqId> = active.keys().copied().collect();
        for id in ids {
            let t = active.remove(&id).unwrap();
            s.finish(id, &t, t.len());
        }
        if let Err(e) = s.check() {
            return Err(e);
        }
        Ok(())
    });
}
