//! Live end-to-end telemetry test (ISSUE 6): a real nano-tier training run
//! with the metrics plane ON must produce per-policy TTFT/e2e latency
//! histograms, a scrapeable Prometheus `/metrics` body, and a JSONL stream
//! carrying gate headroom and per-replica inbox depth.
//!
//! ONE `#[test]` on purpose: the enable flag is process-global, and the
//! disabled-path assertions must run before anything in this process turns
//! the plane on. Phases are ordered inside the single test body.

use std::path::PathBuf;

use areal::config::{Config, Mode};
use areal::coordinator::System;
use areal::runtime::artifacts::test_artifacts_dir;
use areal::util::json::Json;
use areal::util::metrics;

macro_rules! require_artifacts {
    () => {
        if test_artifacts_dir().is_none() {
            eprintln!("skipping: AOT artifacts not built (run `make artifacts`)");
            return;
        }
    };
}

#[test]
fn telemetry_plane_end_to_end() {
    // ---- phase 1: with the plane off (process default), every write is
    // dropped — one-shots and held handles alike --------------------------
    assert!(!metrics::enabled(), "plane must start disabled");
    metrics::inc("live_disabled_ctr", 3);
    metrics::set("live_disabled_gauge", 1.5);
    metrics::observe("live_disabled_hist", 0.5);
    let held = metrics::counter("live_disabled_held");
    held.add(7);
    let s = metrics::snapshot();
    assert_eq!(s.counter("live_disabled_ctr").unwrap_or(0), 0);
    assert_eq!(s.gauge("live_disabled_gauge").map(|_| 1).unwrap_or(0), 0);
    assert_eq!(s.hist("live_disabled_hist").map_or(0, |h| h.count()), 0);
    assert_eq!(held.get(), 0, "held handle also gated by the global flag");

    // ---- phase 2: live system run with the plane on ---------------------
    require_artifacts!();
    let out = std::env::temp_dir()
        .join(format!("areal_metrics_live_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&out);
    let mut cfg = Config::default();
    cfg.artifacts_dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    cfg.tier = "nano".into();
    cfg.task = "sort".into();
    cfg.level_lo = 2;
    cfg.level_hi = 3;
    cfg.group_size = 4;
    cfg.global_batch = 8;
    cfg.ppo_minibatches = 2;
    cfg.ppo_steps = 3;
    cfg.n_rollout_workers = 1;
    cfg.reward_threads = 1;
    cfg.sft_steps = 2;
    cfg.eval_samples = 0;
    cfg.token_budget = 256;
    cfg.mode = Mode::Async;
    cfg.max_staleness = Some(4);
    cfg.metrics = true;
    cfg.metrics_interval_s = 0.05; // several JSONL snapshots even in a short run
    cfg.out_dir = out.clone();
    cfg.validate().unwrap();
    let sys = System::build(cfg).expect("build (run `make artifacts` first)");
    let report = sys.run().expect("run");
    assert_eq!(report.steps.len(), 3);

    // ---- phase 3: registry contents -------------------------------------
    let snap = metrics::snapshot();
    assert!(
        snap.counter("areal_sched_admitted_total").unwrap_or(0) > 0,
        "scheduler admissions recorded"
    );
    assert!(snap.counter("areal_gen_tokens_total").unwrap_or(0) > 0);
    assert!(snap.counter("areal_train_tokens_total").unwrap_or(0) > 0);
    let steps_hist = snap.hist("areal_train_step_seconds").expect("train step hist");
    assert_eq!(steps_hist.count(), 3, "one sample per PPO step");
    assert!(snap.hist("areal_staleness_versions").map_or(0, |h| h.count()) >= 24);

    // the tentpole: per-policy latency histograms from the request spans
    let ttft = snap
        .hists
        .iter()
        .find(|(k, _)| k.starts_with("areal_ttft_seconds"))
        .map(|(k, h)| {
            assert!(k.contains("policy=\""), "TTFT series labeled by policy: {k}");
            h
        })
        .expect("TTFT histogram recorded");
    let e2e = snap
        .hists
        .iter()
        .find(|(k, _)| k.starts_with("areal_e2e_seconds"))
        .map(|(_, h)| h)
        .expect("e2e histogram recorded");
    assert!(ttft.count() > 0 && e2e.count() > 0);
    // structural oracle on the percentile walk: finite, positive, ordered
    for h in [ttft, e2e] {
        let (p50, p90, p99) =
            (h.percentile(50.0), h.percentile(90.0), h.percentile(99.0));
        assert!(p50.is_finite() && p50 > 0.0, "p50 {p50}");
        assert!(h.min <= p50 && p50 <= p90 && p90 <= p99 && p99 <= h.max,
                "ordered percentiles: min={} p50={p50} p90={p90} p99={p99} max={}",
                h.min, h.max);
    }
    // every trajectory observes both series, and per-sample e2e (submit ->
    // reward hand-off) strictly contains TTFT (submit -> first token), so
    // the exact CAS-accumulated means must respect the same order
    assert_eq!(ttft.count(), e2e.count(), "paired observations");
    assert!(e2e.mean() >= ttft.mean(), "e2e {} < ttft {}", e2e.mean(), ttft.mean());

    // ---- phase 4: Prometheus /metrics over a live listener --------------
    // (the in-run listener bound an ephemeral port; a fresh one serves the
    // same process-global registry)
    let mut srv = metrics::MetricsServer::serve("127.0.0.1:0", None).expect("bind");
    let body = metrics::scrape(&srv.local_addr()).expect("scrape");
    srv.stop();
    assert!(body.contains("areal_ttft_seconds"), "{body}");
    assert!(body.contains("quantile=\"0.99\""));
    assert!(body.contains("areal_sched_admitted_total"));
    assert!(body.contains("# TYPE areal_train_step_seconds summary"));

    // ---- phase 5: the JSONL stream the exporter appended during the run -
    let text = std::fs::read_to_string(out.join("metrics_live.jsonl"))
        .expect("exporter wrote metrics_live.jsonl");
    let lines: Vec<&str> = text.lines().collect();
    assert!(lines.len() >= 2, "periodic + final snapshots, got {}", lines.len());
    for l in &lines {
        Json::parse(l).expect("every line is valid json");
    }
    // the quotes in labeled names are escaped inside the JSON text, so
    // check through the parsed object, not substring search
    let last = Json::parse(lines.last().unwrap()).unwrap();
    let gauges = last.get("gauges").expect("gauges object");
    assert!(gauges.get("areal_gate_headroom_batches").is_some(),
            "poll closure sampled the gate");
    assert!(gauges.get("areal_inbox_depth{replica=\"0\"}").is_some(),
            "poll closure sampled inbox depth");
    let _ = std::fs::remove_dir_all(&out);
}
