//! Out-of-process worker binary, end to end (ISSUE 10, DESIGN.md §13):
//! spawn the real `areal` binary as a child process in worker mode, let it
//! compile its own engine from the artifact manifest, stream the published
//! weights chunk-by-chunk over loopback, serve a full rollout round, and
//! exit cleanly on Drain. The coordinator side here is the exact wiring
//! `system.rs` installs on a socket endpoint — router pull hook, weight
//! streamer, result sink — assembled by hand so the test can watch every
//! seam.
//!
//! Acceptance (vs an in-process baseline running the same engine, seed,
//! and serve loop skeleton over a `LocalTransport` router):
//!
//! - zero lost requests: every submitted request comes back as exactly one
//!   trajectory, no GRPO group left partial;
//! - bitwise-equal routing: the placement trace matches;
//! - bitwise-equal prefill accounting: the child's final `stats` frame
//!   reports the same cached/computed prefill token counts the baseline
//!   engine measures, and the sampled completions themselves are
//!   identical — the process boundary changes delivery, not behavior;
//! - the weights crossed the wire through the chunked stream (no shared
//!   memory exists between the processes to hand a `ParamSet` over).
//!
//! Requires `make artifacts` (skips otherwise), like the other
//! integration suites.

use std::path::PathBuf;
use std::process::{Command, Stdio};
use std::sync::atomic::AtomicU64;
use std::sync::{Arc, Weak};
use std::time::{Duration, Instant};

use areal::config::Config;
use areal::coordinator::{
    Event, GenEngine, GenRouter, ParamServer, ReplayBuffer, ResultSink, Trace, Trajectory,
    WeightStreamer,
};
use areal::reward::RewardService;
use areal::runtime::artifacts::test_artifacts_dir;
use areal::runtime::{Engine, Manifest, ParamSet};
use areal::serve::{
    Control, Pulled, ReplicaTransport, Request, RoutePolicy, RouterCfg, ServeCfg,
    SocketTransport,
};
use areal::tasks::{AdditionTask, Prompt};
use areal::text::tokenizer::Tokenizer;

macro_rules! require_artifacts {
    () => {
        if test_artifacts_dir().is_none() {
            eprintln!("skipping: AOT artifacts not built (run `make artifacts`)");
            return;
        }
    };
}

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// The knobs both sides must agree on. Everything else stays at the
/// config defaults the child also loads.
fn shared_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.artifacts_dir = artifacts_dir();
    cfg.tier = "nano".into();
    cfg.seed = 11;
    cfg
}

/// Replicates `run_worker`'s ServeCfg derivation so the baseline engine
/// is configured exactly like the child's.
fn serve_cfg(engine: &Engine, cfg: &Config) -> ServeCfg {
    let c = &engine.spec.config;
    let bs = if cfg.kv_block_size == 0 {
        ServeCfg::default_block_size(c.max_seq)
    } else {
        cfg.kv_block_size
    };
    let mut s = ServeCfg::for_engine(c.gen_batch, c.max_seq, bs);
    if cfg.kv_blocks > 0 {
        s.num_blocks = cfg.kv_blocks;
    }
    s.prefix_cache = cfg.prefix_cache;
    s
}

/// Two GRPO groups of four identical prompts each (the group-mean
/// baseline samples the same prompt `group_size` times), in submission
/// order.
fn prompt_round() -> Vec<Prompt> {
    let mut out = Vec::new();
    for g in 0..2u64 {
        let (a, b) = (g + 1, 2 * g + 3);
        let p = Prompt {
            text: format!("Q{a}+{b}="),
            meta: format!("add:{a},{b}"),
            level: 1,
            group: g,
        };
        for _ in 0..4 {
            out.push(p.clone());
        }
    }
    out
}

fn rcfg(serve: &ServeCfg) -> RouterCfg {
    RouterCfg::new(RoutePolicy::Probe, serve.block_size, 0).probe_ttl(u64::MAX)
}

/// Sorted multiset of (group, token stream) for order-insensitive
/// bit-exact comparison of completions across the two runs.
fn traj_key(trajs: &[Trajectory]) -> Vec<(u64, Vec<i32>)> {
    let mut k: Vec<(u64, Vec<i32>)> =
        trajs.iter().map(|t| (t.prompt.group, t.tokens.clone())).collect();
    k.sort();
    k
}

#[test]
fn worker_binary_round_matches_in_process_baseline() {
    require_artifacts!();
    let cfg = shared_cfg();
    let manifest = Manifest::load(&cfg.artifacts_dir).expect("manifest");
    let spec = manifest.tier(&cfg.tier).expect("nano tier");
    let engine = Arc::new(Engine::load(spec).expect("compile artifacts"));
    let serve = serve_cfg(&engine, &cfg);
    let prompts = prompt_round();
    let total = prompts.len() as u64;

    // ---- coordinator side: one socket endpoint, wired as system.rs does
    let endpoint =
        SocketTransport::<Prompt>::listen("127.0.0.1:0", cfg.socket_max_frame).unwrap();
    let transports: Vec<Arc<dyn ReplicaTransport<Prompt>>> =
        vec![Arc::clone(&endpoint) as Arc<dyn ReplicaTransport<Prompt>>];
    let router = Arc::new(GenRouter::new_with(transports, rcfg(&serve)));
    let weak: Weak<GenRouter> = Arc::downgrade(&router);
    endpoint.set_pull_fn(Arc::new(move |epoch, max_n| match weak.upgrade() {
        Some(r) => r.pull_at(0, epoch, max_n),
        None => Pulled { reqs: Vec::new(), stolen: None },
    }));
    let params = ParamSet::init(&engine, [cfg.seed as u32, 0x9e37]).expect("init params");
    let server = ParamServer::new(Arc::clone(&params));
    let streamer = WeightStreamer::new(Arc::clone(&server), 4096, true);
    let (s1, s2, s3) = (Arc::clone(&streamer), Arc::clone(&streamer), Arc::clone(&streamer));
    endpoint.set_weight_source(
        Arc::new(move |have| s1.plan(0, have)),
        Arc::new(move |v, i| s2.chunk(0, v, i)),
    );
    endpoint.set_closed_fn(Arc::new(move || s3.note_closed(0)));
    let buffer = Arc::new(ReplayBuffer::new());
    let reward = Arc::new(RewardService::new(Arc::new(AdditionTask), 1));
    let trace = Arc::new(Trace::new(true));
    let sink = ResultSink::new(
        Arc::clone(&buffer),
        reward,
        Arc::clone(&trace),
        Arc::new(AtomicU64::new(0)),
        "probe",
    );
    let sink_c = Arc::clone(&sink);
    endpoint.set_msg_fn(Arc::new(move |kind, msg| sink_c.handle(0, kind, msg)));
    let weak_t = Arc::downgrade(&endpoint);
    endpoint.set_join_fn(Arc::new(move || match weak_t.upgrade() {
        Some(ep) => {
            ep.reopen();
            true
        }
        None => false,
    }));

    // submit the whole round BEFORE the child connects, so its first
    // refill pull sees the same queue the baseline's does
    let tok = Tokenizer::new();
    let mut socket_placements = Vec::new();
    for p in &prompts {
        let tokens = tok.encode_bos(&p.text);
        socket_placements.push(router.submit(Request::new(p.group, tokens, p.clone())));
    }

    // ---- the real worker binary, as a separate OS process
    let mut child = Command::new(env!("CARGO_BIN_EXE_areal"))
        .arg("worker")
        .arg(format!("connect={}", endpoint.local_addr()))
        .arg(format!("artifacts_dir={}", cfg.artifacts_dir.display()))
        .arg(format!("tier={}", cfg.tier))
        .arg(format!("seed={}", cfg.seed))
        .stdin(Stdio::null())
        .stdout(Stdio::inherit())
        .stderr(Stdio::inherit())
        .spawn()
        .expect("spawn areal worker");

    // every request comes back as exactly one accepted trajectory
    let t0 = Instant::now();
    while sink.accepted() < total {
        if t0.elapsed() > Duration::from_secs(180) {
            let _ = child.kill();
            panic!("worker served {}/{total} results before timeout", sink.accepted());
        }
        if let Ok(Some(status)) = child.try_wait() {
            panic!("worker exited early ({status}) after {} results", sink.accepted());
        }
        std::thread::sleep(Duration::from_millis(20));
    }

    // drain: the child finishes its inbox, reports stats, and exits 0
    router.broadcast(Control::Drain);
    let status = loop {
        if let Some(s) = child.try_wait().expect("wait child") {
            break s;
        }
        if t0.elapsed() > Duration::from_secs(240) {
            let _ = child.kill();
            panic!("worker never exited after Drain");
        }
        std::thread::sleep(Duration::from_millis(20));
    };
    assert!(status.success(), "worker exit status: {status}");
    assert_eq!(sink.accepted(), total, "zero lost, zero extra");
    assert_eq!(sink.duplicates(), 0, "clean run resends nothing");
    assert!(
        streamer.chunks_served() > 0,
        "weights must cross the wire through the chunked stream"
    );
    assert_eq!(router.queued_total(), 0, "inbox fully served");

    // reward verification lands every trajectory in the replay buffer
    let socket_trajs = buffer.pop_batch(total as usize).expect("all trajectories land");
    for g in 0..2u64 {
        assert_eq!(
            socket_trajs.iter().filter(|t| t.prompt.group == g).count(),
            4,
            "GRPO group {g} left partial"
        );
    }
    // the child's final stats frame carries its prefill accounting
    let mut child_stats: Option<(u64, u64)> = None;
    for s in trace.snapshot() {
        if let Event::CacheStat { cached_tokens, computed_tokens, .. } = s.event {
            child_stats = Some((cached_tokens, computed_tokens));
        }
    }
    let child_stats = child_stats.expect("worker reported prefill stats before exit");
    endpoint.shutdown();

    // ---- in-process baseline: same engine artifacts, same seed, same
    // serve-loop skeleton, LocalTransport router
    let router_b = Arc::new(GenRouter::new(1, rcfg(&serve)));
    let mut local_placements = Vec::new();
    for p in &prompts {
        let tokens = tok.encode_bos(&p.text);
        local_placements.push(router_b.submit(Request::new(p.group, tokens, p.clone())));
    }
    let params_b = ParamSet::init(&engine, [cfg.seed as u32, 0x9e37]).expect("init params");
    let mut gen = GenEngine::with_serve(
        Arc::clone(&engine),
        params_b,
        0,
        cfg.temperature,
        cfg.seed,
        Some(serve),
    );
    gen.configure_prefix_prefill(cfg.prefix_prefill, cfg.prefill_bucket_min);
    let b = gen.n_slots();
    let mut baseline_trajs: Vec<Trajectory> = Vec::new();
    let deadline = Instant::now() + Duration::from_secs(180);
    while (baseline_trajs.len() as u64) < total {
        assert!(Instant::now() < deadline, "baseline starved");
        // the exact refill/prefill/decode skeleton `serve_once` runs; the
        // engine-state conditions (and therefore the RNG cadence) evolve
        // identically, which is what makes the comparison bitwise
        let capacity = gen.fill_capacity();
        let empties = gen.empty_slots();
        let refill_wave = gen.all_empty()
            || gen.needs_prefill()
            || (empties as f64) >= (b as f64) * cfg.refill_fraction;
        if refill_wave {
            if capacity > 0 {
                let epoch = router_b.epoch(0);
                let mut reqs = router_b.pull_at(0, epoch, capacity).reqs;
                for r in &mut reqs {
                    r.span.stamp_admit();
                }
                if !reqs.is_empty() {
                    gen.fill_requests(reqs).unwrap();
                }
            }
            if gen.admission_feasible() {
                gen.request_prefill();
            }
        }
        if gen.needs_prefill() && (gen.waiting() > 0 || !gen.all_empty()) {
            gen.prefill().unwrap();
        }
        if !gen.all_empty() && !gen.needs_prefill() {
            baseline_trajs.extend(gen.decode_chunk().unwrap());
        }
    }

    // ---- equivalence
    assert_eq!(
        socket_placements, local_placements,
        "routing diverged across the process boundary"
    );
    let s = gen.serve_stats();
    assert_eq!(
        child_stats,
        (s.prefill_tokens_cached, s.prefill_tokens_computed),
        "prefill accounting diverged across the process boundary"
    );
    assert!(
        s.prefill_tokens_cached > 0,
        "the round must exercise the prefix cache (identical group prompts)"
    );
    assert_eq!(
        traj_key(&socket_trajs),
        traj_key(&baseline_trajs),
        "sampled completions diverged across the process boundary"
    );
}

#[test]
fn worker_binary_refuses_to_start_without_connect() {
    // no artifacts needed: the argument check fires before the manifest
    // loads, and a clear error beats a hang for an operator typo
    let out = Command::new(env!("CARGO_BIN_EXE_areal"))
        .arg("worker")
        .output()
        .expect("run areal worker");
    assert!(!out.status.success(), "worker without connect= must fail");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(
        err.contains("connect"),
        "error must name the missing key, got: {err}"
    );
}
