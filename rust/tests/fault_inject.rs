//! Fault-injection test plane for the out-of-process worker path
//! (ISSUE 10, DESIGN.md §13): a frame-aware TCP proxy (`support/proxy.rs`)
//! sits between a [`SocketWorker`] and its [`SocketTransport`] endpoint
//! and injects the failures a loopback test never sees on its own —
//! severed links mid-pull and mid-weight-stream, torn (truncated) frames,
//! duplicated frames, added latency. Each scenario asserts the designed
//! recovery invariant, not just survival:
//!
//! - a kill mid-pull loses zero requests: the epoch fence salvages the
//!   inbox and the worker's `resub` returns the in-flight ones, so every
//!   GRPO group is served whole;
//! - a kill mid-weight-stream resumes from the last assembled chunk (the
//!   reconnect handshake quotes `WeightAssembler::progress`), it does not
//!   restart — every chunk crosses the wire once;
//! - a truncated frame desynchronizes only the connection, never the
//!   assembly: the resumed stream completes bit-exact;
//! - a version retired mid-stream answers stale and the worker
//!   fast-forwards to the latest (catch-up, not replay);
//! - a duplicated chunk frame shifts the RPC stream one reply behind; the
//!   assembler's duplicate-drop cursor realigns it and the blob still
//!   assembles bit-exact.
//!
//! These tests run the protocol machinery directly (no model artifacts
//! needed); `worker_proc.rs` covers the same wire with a real child
//! process and a real engine.

#[path = "support/proxy.rs"]
mod proxy;

use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use areal::coordinator::{ParamServer, WeightStreamer};
use areal::runtime::executor::SendLiteral;
use areal::runtime::params::decode_param_set;
use areal::runtime::{HostTensor, ParamSet, Version};
use areal::serve::{
    ReplicaTransport, Request, SocketTransport, SocketWorker, WeightAssembler,
};

use proxy::FaultProxy;

fn req(group: u64, tokens: Vec<i32>) -> Request<()> {
    Request::new(group, tokens, ())
}

fn pset(v: Version) -> Arc<ParamSet> {
    let lit = HostTensor::scalar_f32(v as f32).to_literal().unwrap();
    ParamSet::with_version(vec![SendLiteral(lit)], v)
}

fn wait_until(what: &str, mut f: impl FnMut() -> bool) {
    let t0 = Instant::now();
    while !f() {
        assert!(t0.elapsed() < Duration::from_secs(10), "timed out waiting: {what}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// Stand-in for the fleet's salvage wiring on a single endpoint: the
/// disconnect hook collects the fenced inbox salvage plus any orphaned
/// in-flight requests into a shared stash the test re-routes, exactly the
/// role `Router::remove_replica_at` plays in the full system.
fn wire_salvage(t: &Arc<SocketTransport<()>>) -> Arc<Mutex<Vec<Request<()>>>> {
    let stash: Arc<Mutex<Vec<Request<()>>>> = Arc::new(Mutex::new(Vec::new()));
    let weak = Arc::downgrade(t);
    let s2 = Arc::clone(&stash);
    t.set_disconnect_fn(Arc::new(move |epoch, orphans| {
        let mut s = s2.lock().unwrap();
        if let Some(ep) = weak.upgrade() {
            // fenced: salvages only if `epoch` is still the current tenancy
            if let Some(salvaged) = ep.close_salvage_at(epoch) {
                s.extend(salvaged);
            }
        }
        s.extend(orphans);
    }));
    let weak = Arc::downgrade(t);
    t.set_join_fn(Arc::new(move || match weak.upgrade() {
        Some(ep) => {
            ep.reopen();
            true
        }
        None => false,
    }));
    stash
}

#[test]
fn kill_mid_pull_salvages_every_request_and_groups_stay_whole() {
    let t = SocketTransport::<()>::listen("127.0.0.1:0", 1 << 20).unwrap();
    let stash = wire_salvage(&t);
    // two GRPO groups of four: wholeness means each group id is served
    // exactly four times across the failure
    for g in 0..2u64 {
        for k in 0..4i32 {
            ReplicaTransport::submit(&*t, req(g, vec![10 * g as i32 + k])).unwrap();
        }
    }
    let px = FaultProxy::start(&t.local_addr());

    // the worker pulls three requests through the proxy, then the link dies
    let mut w = SocketWorker::<()>::connect(px.addr(), 1 << 20).unwrap();
    let old_epoch = w.epoch();
    let pulled = w.pull(3, None).unwrap();
    assert_eq!(pulled.reqs.len(), 3);
    px.sever_now();

    // the endpoint notices the disconnect, fences the tenancy, and the
    // hook salvages the five still-queued requests
    wait_until("disconnect salvage", || stash.lock().unwrap().len() == 5);
    assert!(!t.is_open(), "lost tenancy is closed behind the fence");

    // reconnect-with-catch-up: join revives the slot under a fresh epoch,
    // and resub hands the three in-flight requests back through the same
    // fenced re-route path (quoting the OLD epoch — stale removal is a
    // no-op, the requests still land)
    let mut w2 = SocketWorker::<()>::connect_auth(&t.local_addr(), 1 << 20, None, true)
        .unwrap();
    assert!(w2.open());
    assert!(w2.epoch() > old_epoch, "revived slot serves a fresh epoch");
    let n = w2.resubmit(old_epoch, &pulled.reqs).unwrap();
    assert_eq!(n, 3);
    wait_until("resub re-route", || stash.lock().unwrap().len() == 8);

    // the fleet re-routes the stash (here: back into the revived inbox)
    for r in stash.lock().unwrap().drain(..) {
        ReplicaTransport::submit(&*t, r).unwrap();
    }
    let served = w2.pull(16, None).unwrap();
    assert!(!served.fenced);
    assert_eq!(served.reqs.len(), 8, "zero requests lost across the kill");
    for g in 0..2u64 {
        assert_eq!(
            served.reqs.iter().filter(|r| r.group == g).count(),
            4,
            "GRPO group {g} left partial"
        );
    }
    assert_eq!(t.queued(), 0);
    w2.bye();
}

/// Wire a streamer to an endpoint the way `system.rs` does (weight source
/// + closed hook for cursor cleanup), all for replica slot 0.
fn wire_streamer(
    t: &Arc<SocketTransport<()>>,
    ws: &Arc<WeightStreamer>,
) {
    let plan_ws = Arc::clone(ws);
    let chunk_ws = Arc::clone(ws);
    t.set_weight_source(
        Arc::new(move |have| plan_ws.plan(0, have)),
        Arc::new(move |v, i| chunk_ws.chunk(0, v, i)),
    );
    let closed_ws = Arc::clone(ws);
    t.set_closed_fn(Arc::new(move || closed_ws.note_closed(0)));
}

/// Drive a weight stream to completion the way the worker binary does
/// (`stream_to_latest`): re-handshake on stale, offer under the echoed
/// index, let the assembler cursor choose what to ask for next.
fn stream_all(
    w: &mut SocketWorker<()>,
    asm: &mut WeightAssembler,
) -> (Version, Vec<u8>) {
    loop {
        let (v, _total, start) = w
            .weight_begin(asm.progress())
            .unwrap()
            .expect("endpoint has a weight source");
        if start == 0 {
            asm.reset_partial();
        }
        let mut i = start;
        loop {
            match w.weight_pull(v, i).unwrap() {
                Some((ri, n, data)) => match asm.offer(v, ri, n, &data) {
                    Ok(Some(done)) => return done,
                    Ok(None) => i = asm.progress().map(|(_, k)| k).unwrap_or(0),
                    Err(_) => {
                        asm.reset_partial();
                        break;
                    }
                },
                None => {
                    // wstale: fast-forward via a fresh handshake
                    asm.reset_partial();
                    break;
                }
            }
        }
    }
}

#[test]
fn kill_mid_weight_stream_resumes_from_last_acked_chunk() {
    let ps = ParamServer::new(pset(3));
    let ws = WeightStreamer::new(Arc::clone(&ps), 8, true);
    let t = SocketTransport::<()>::listen("127.0.0.1:0", 1 << 20).unwrap();
    wire_streamer(&t, &ws);
    let px = FaultProxy::start(&t.local_addr());
    px.ctl.delay_ms.store(2, Ordering::SeqCst); // a little wire latency

    let mut asm = WeightAssembler::new();
    let (v, total, start) = {
        let mut w = SocketWorker::<()>::connect(px.addr(), 1 << 20).unwrap();
        let (v, total, start) = w.weight_begin(None).unwrap().expect("plan");
        assert_eq!(start, 0);
        assert!(total >= 4, "scalar set must span several 8-byte chunks");
        // two chunks land, then the link dies mid-broadcast
        for i in 0..2usize {
            let (ri, n, data) = w.weight_pull(v, i).unwrap().expect("chunk");
            assert!(asm.offer(v, ri, n, &data).unwrap().is_none());
        }
        px.sever_now();
        (v, total, start)
    };
    assert_eq!(asm.progress(), Some((v, 2)), "partial assembly survives the kill");
    wait_until("cursor cleanup", || ws.cursor_count() == 0);

    // reconnect straight to the endpoint: the handshake quotes the
    // partial assembly and the plan RESUMES at chunk 2, not 0
    px.ctl.delay_ms.store(0, Ordering::SeqCst);
    let mut w = SocketWorker::<()>::connect(&t.local_addr(), 1 << 20).unwrap();
    let (v2, total2, start2) = w.weight_begin(asm.progress()).unwrap().expect("plan");
    assert_eq!((v2, total2, start2), (v, total, 2), "resumed, not restarted");
    let (dv, blob) = stream_all(&mut w, &mut asm);
    assert_eq!(dv, 3);
    assert_eq!(decode_param_set(&blob).unwrap().version, 3);
    // every chunk crossed the wire exactly once across both connections
    assert_eq!(ws.chunks_served(), total as u64);
    w.bye();
}

#[test]
fn truncated_weight_frame_kills_the_link_but_not_the_assembly() {
    let ps = ParamServer::new(pset(9));
    let ws = WeightStreamer::new(Arc::clone(&ps), 8, true);
    let t = SocketTransport::<()>::listen("127.0.0.1:0", 1 << 20).unwrap();
    wire_streamer(&t, &ws);
    let px = FaultProxy::start(&t.local_addr());

    let mut asm = WeightAssembler::new();
    let mut w = SocketWorker::<()>::connect(px.addr(), 1 << 20).unwrap();
    let (v, total, _) = w.weight_begin(None).unwrap().expect("plan");
    let (ri, n, data) = w.weight_pull(v, 0).unwrap().expect("chunk");
    asm.offer(v, ri, n, &data).unwrap();
    // the next chunk frame is torn mid-body: its length prefix promises
    // bytes that never arrive, so the read must fail — a short frame must
    // never be delivered as if it were whole
    px.ctl.truncate_next.store(true, Ordering::SeqCst);
    assert!(w.weight_pull(v, 1).is_err(), "torn frame is a wire error");
    assert_eq!(asm.progress(), Some((v, 1)), "assembly unaffected by the tear");

    // reconnect and resume from the chunk the tear destroyed
    let mut w = SocketWorker::<()>::connect(&t.local_addr(), 1 << 20).unwrap();
    let (v2, _, start2) = w.weight_begin(asm.progress()).unwrap().expect("plan");
    assert_eq!((v2, start2), (v, 1));
    let (dv, blob) = stream_all(&mut w, &mut asm);
    assert_eq!(dv, 9);
    assert_eq!(decode_param_set(&blob).unwrap().version, 9);
    // the torn chunk was served server-side before the tear, so it (and
    // only it) crosses the wire twice
    assert_eq!(ws.chunks_served(), total as u64 + 1);
    w.bye();
}

#[test]
fn stale_version_mid_stream_fast_forwards_to_latest() {
    let ps = ParamServer::new(pset(1));
    let ws = WeightStreamer::new(Arc::clone(&ps), 8, true);
    let t = SocketTransport::<()>::listen("127.0.0.1:0", 1 << 20).unwrap();
    wire_streamer(&t, &ws);

    let mut asm = WeightAssembler::new();
    let mut w = SocketWorker::<()>::connect(&t.local_addr(), 1 << 20).unwrap();
    let (v, _, _) = w.weight_begin(None).unwrap().expect("plan");
    assert_eq!(v, 1);
    for i in 0..2usize {
        let (ri, n, data) = w.weight_pull(v, i).unwrap().expect("chunk");
        asm.offer(v, ri, n, &data).unwrap();
    }
    // the trainer publishes v5 mid-stream: v1 is retired on the spot
    ps.publish(pset(5));
    assert!(w.weight_pull(v, 2).unwrap().is_none(), "retired version answers stale");
    // the worker's catch-up loop re-handshakes and fast-forwards: the new
    // plan streams v5 from scratch and completes
    let (dv, blob) = stream_all(&mut w, &mut asm);
    assert_eq!(dv, 5);
    assert_eq!(decode_param_set(&blob).unwrap().version, 5);
    assert_eq!(asm.done_version(), Some(5));
    // late v1 chunks after the fast-forward are dropped, not assembled
    assert!(asm.offer(1, 2, 4, &[0u8; 8]).unwrap().is_none());
    w.bye();
}

#[test]
fn duplicated_chunk_frames_realign_and_assemble_bit_exact() {
    let ps = ParamServer::new(pset(4));
    let ws = WeightStreamer::new(Arc::clone(&ps), 8, true);
    let t = SocketTransport::<()>::listen("127.0.0.1:0", 1 << 20).unwrap();
    wire_streamer(&t, &ws);
    let px = FaultProxy::start(&t.local_addr());

    let mut asm = WeightAssembler::new();
    let mut w = SocketWorker::<()>::connect(px.addr(), 1 << 20).unwrap();
    let (v, total, _) = w.weight_begin(None).unwrap().expect("plan");
    let (ri, n, data) = w.weight_pull(v, 0).unwrap().expect("chunk");
    asm.offer(v, ri, n, &data).unwrap();
    // duplicate the next chunk frame: from here on every reply is one
    // request behind — the assembler must drop the duplicates (keyed on
    // the ECHOED index) and the cursor must keep re-asking until the
    // stream realigns. Armed after the handshake so the duplicated frame
    // is a wchunk, the interesting case.
    px.ctl.duplicate_next.store(true, Ordering::SeqCst);
    let mut done = None;
    let mut i = asm.progress().map(|(_, k)| k).unwrap_or(0);
    while done.is_none() {
        let (ri, n, data) = w.weight_pull(v, i).unwrap().expect("chunk");
        done = asm.offer(v, ri, n, &data).unwrap();
        i = asm.progress().map(|(_, k)| k).unwrap_or(0);
    }
    let (dv, blob) = done.unwrap();
    assert_eq!(dv, 4);
    assert_eq!(decode_param_set(&blob).unwrap().version, 4);
    assert_eq!(asm.done_version(), Some(4));
    assert!(
        ws.chunks_served() > total as u64,
        "realignment re-pulls chunks; the duplicate cannot be free"
    );
    // the one extra injected reply still sits in the socket buffer; the
    // connection is otherwise healthy — drop it without a bye and let the
    // endpoint's disconnect path clean up
    drop(w);
    wait_until("cursor cleanup", || ws.cursor_count() == 0);
}
