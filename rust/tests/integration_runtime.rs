//! Runtime integration: cross-artifact consistency on the nano tier —
//! the Rust-side counterparts of the python test_model invariants, plus
//! checkpoint/resume and failure injection. Requires `make artifacts`.

use std::sync::Arc;

use areal::coordinator::GenEngine;
use areal::runtime::artifacts::test_artifacts_dir;
use areal::runtime::{params, Engine, HostTensor, Manifest, ParamSet, TrainState};
use areal::tasks::{SortTask, Task};
use areal::util::rng::Rng;

fn manifest() -> Option<Manifest> {
    let dir = test_artifacts_dir()?;
    Some(Manifest::load(&dir).expect("manifest load"))
}

fn engine_full() -> Option<Arc<Engine>> {
    Some(Arc::new(
        Engine::load(manifest()?.tier("nano").unwrap()).unwrap(),
    ))
}

macro_rules! or_skip {
    ($opt:expr) => {
        match $opt {
            Some(x) => x,
            None => {
                eprintln!("skipping: AOT artifacts not built (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn behav_logps_match_logprob_artifact() {
    // Proposition-1 bookkeeping across artifacts IN RUST: the behavior
    // logprobs recorded by prefill/decode at sampling time must equal the
    // teacher-forced logprobs the trainer's `logprob` artifact recomputes
    // for the same tokens (this is exactly what makes prox-recompute and
    // importance ratios correct).
    let engine = or_skip!(engine_full());
    let spec = engine.spec.clone();
    let params = ParamSet::init(&engine, [5, 6]).unwrap();
    let mut gen = GenEngine::new(Arc::clone(&engine), Arc::clone(&params), 0, 1.0, 42);

    let task = SortTask;
    let mut rng = Rng::new(9);
    let mut prompts: Vec<_> = (0..4).map(|_| task.sample(&mut rng, 2)).collect();
    gen.fill(&mut prompts).unwrap();
    let trajs = gen.drain().unwrap();
    assert!(!trajs.is_empty());

    let (bt, t) = (spec.config.train_batch, spec.config.max_seq);
    let mut tokens = vec![0i32; bt * t];
    for (row, tr) in trajs.iter().enumerate() {
        tokens[row * t..row * t + tr.tokens.len()].copy_from_slice(&tr.tokens);
    }
    let tokens_l = HostTensor::i32(vec![bt, t], tokens).to_literal().unwrap();
    let mut inputs: Vec<&xla::Literal> = params.refs();
    inputs.push(&tokens_l);
    let outs = engine.run("logprob", &inputs).unwrap();
    let lp = HostTensor::from_literal(outs[0].lit()).unwrap();
    let lp = lp.as_f32().unwrap();

    for (row, tr) in trajs.iter().enumerate() {
        for (k, pos) in (tr.prompt_len..tr.tokens.len()).enumerate() {
            let recomputed = lp[row * t + pos];
            let recorded = tr.behav_logp[k];
            assert!(
                (recomputed - recorded).abs() < 3e-3,
                "token {pos} of traj {row}: recorded {recorded} vs \
                 teacher-forced {recomputed}"
            );
        }
    }
}

#[test]
fn checkpoint_resume_is_bit_identical() {
    // training N sft steps, checkpointing, reloading, and training one more
    // step must equal training N+1 steps directly
    let engine = or_skip!(engine_full());
    let spec = engine.spec.clone();
    let (bt, t) = (spec.config.train_batch, spec.config.max_seq);
    let tokens = HostTensor::i32(
        vec![bt, t],
        (0..bt * t).map(|i| ((i % 40) + 3) as i32).collect(),
    );
    let mask = HostTensor::f32(vec![bt, t], vec![1.0; bt * t]);
    let lr = HostTensor::scalar_f32(1e-3).to_literal().unwrap();

    let run_step = |state: &mut TrainState| {
        let tokens_l = tokens.to_literal().unwrap();
        let mask_l = mask.to_literal().unwrap();
        let step_l = HostTensor::scalar_i32(state.step).to_literal().unwrap();
        let mut inputs: Vec<&xla::Literal> = state.params.refs();
        for m in &state.m {
            inputs.push(m.lit());
        }
        for v in &state.v {
            inputs.push(v.lit());
        }
        inputs.push(&step_l);
        inputs.push(&tokens_l);
        inputs.push(&mask_l);
        inputs.push(&lr);
        let mut outs = engine.run("sft_step", &inputs).unwrap();
        let _metrics = outs.pop().unwrap();
        let _step = outs.pop().unwrap();
        let n = spec.n_params();
        state.v = outs.split_off(2 * n);
        state.m = outs.split_off(n);
        state.params = ParamSet::with_version(outs, state.params.version);
        state.step += 1;
    };

    // path A: 3 straight steps
    let p0 = ParamSet::init(&engine, [7, 8]).unwrap();
    let mut a = TrainState::fresh(&spec, Arc::clone(&p0)).unwrap();
    for _ in 0..3 {
        run_step(&mut a);
    }

    // path B: 2 steps, checkpoint, reload, 1 step
    let mut b = TrainState::fresh(&spec, p0).unwrap();
    for _ in 0..2 {
        run_step(&mut b);
    }
    let path = std::env::temp_dir().join("areal_resume_test.ckpt");
    params::save_checkpoint(&path, &spec, &b).unwrap();
    let mut b2 = params::load_checkpoint(&path, &spec).unwrap();
    assert_eq!(b2.step, 2);
    run_step(&mut b2);

    for (x, y) in a.params.tensors.iter().zip(b2.params.tensors.iter()) {
        let xa = HostTensor::from_literal(x.lit()).unwrap();
        let ya = HostTensor::from_literal(y.lit()).unwrap();
        assert_eq!(xa.as_f32().unwrap(), ya.as_f32().unwrap());
    }
}

#[test]
fn sft_improves_gold_trace_likelihood() {
    // cross-artifact: sft_step updates must increase the logprob artifact's
    // score of the gold traces it trained on
    let engine = or_skip!(engine_full());
    let spec = engine.spec.clone();
    let (bt, t) = (spec.config.train_batch, spec.config.max_seq);
    let task = SortTask;
    let tok = areal::text::Tokenizer::new();
    let mut rng = Rng::new(21);
    let mut tokens = vec![0i32; bt * t];
    let mut mask = vec![0f32; bt * t];
    for row in 0..bt {
        let p = task.sample(&mut rng, 2);
        let gold = task.gold_completion(&p.meta);
        let mut seq = tok.encode_bos(&p.text);
        let plen = seq.len();
        seq.extend(tok.encode(&gold));
        seq.push(areal::text::EOS);
        tokens[row * t..row * t + seq.len()].copy_from_slice(&seq);
        for pos in plen..seq.len() {
            mask[row * t + pos] = 1.0;
        }
    }
    let tokens_t = HostTensor::i32(vec![bt, t], tokens);
    let mask_t = HostTensor::f32(vec![bt, t], mask.clone());

    let score = |params: &ParamSet| -> f64 {
        let tl = tokens_t.to_literal().unwrap();
        let mut inputs: Vec<&xla::Literal> = params.refs();
        inputs.push(&tl);
        let outs = engine.run("logprob", &inputs).unwrap();
        let lp = HostTensor::from_literal(outs[0].lit()).unwrap();
        lp.as_f32()
            .unwrap()
            .iter()
            .zip(&mask)
            .map(|(&l, &m)| (l * m) as f64)
            .sum()
    };

    let p0 = ParamSet::init(&engine, [11, 12]).unwrap();
    let before = score(&p0);
    let mut state = TrainState::fresh(&spec, p0).unwrap();
    let lr = HostTensor::scalar_f32(3e-3).to_literal().unwrap();
    for _ in 0..5 {
        let tl = tokens_t.to_literal().unwrap();
        let ml = mask_t.to_literal().unwrap();
        let sl = HostTensor::scalar_i32(state.step).to_literal().unwrap();
        let mut inputs: Vec<&xla::Literal> = state.params.refs();
        for m in &state.m {
            inputs.push(m.lit());
        }
        for v in &state.v {
            inputs.push(v.lit());
        }
        inputs.push(&sl);
        inputs.push(&tl);
        inputs.push(&ml);
        inputs.push(&lr);
        let mut outs = engine.run("sft_step", &inputs).unwrap();
        outs.pop();
        outs.pop();
        let n = spec.n_params();
        state.v = outs.split_off(2 * n);
        state.m = outs.split_off(n);
        state.params = ParamSet::with_version(outs, 0);
        state.step += 1;
    }
    let after = score(&state.params);
    assert!(
        after > before + 1.0,
        "gold-trace loglik should rise: {before} -> {after}"
    );
}

#[test]
fn engine_rejects_malformed_artifact() {
    // failure injection: a corrupted HLO file must fail cleanly at load
    let dir = std::env::temp_dir().join("areal_bad_artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    let m = or_skip!(manifest());
    let spec = m.tier("nano").unwrap();
    // copy manifest dir layout with one truncated file
    let mut bad = spec.clone();
    let bad_file = dir.join("nano_init.hlo.txt");
    std::fs::write(&bad_file, "HloModule garbage, this is not valid {").unwrap();
    if let Some(e) = bad.entrypoints.get_mut("init") {
        e.file = bad_file;
    }
    let err = Engine::load_subset(&bad, Some(&["init"]));
    assert!(err.is_err(), "corrupted artifact must not load");
}
