//! Router equivalence suite across transport backends (ISSUE 4
//! acceptance): the same scripted request trace must produce identical
//! routing decisions under `RoutePolicy::Probe` whether the replicas sit
//! behind in-process `LocalTransport` inboxes or `SocketTransport`
//! endpoints with workers speaking the frame protocol; replica loss must
//! salvage with zero lost requests and no partial GRPO group on both; and
//! `update_weights`/drain fan-out must reach every replica on both.
//!
//! Determinism notes: the local fleet runs with `probe_ttl = u64::MAX`,
//! so its probe snapshots refresh only on worker pulls — exactly the
//! cadence at which a socket worker ships its snapshot piggybacked on
//! each pull frame. Both backends therefore score placements from the
//! same measured state, and the serving harness below drives schedulers
//! in sorted-id order so the two runs evolve bit-identically.

use std::collections::{BTreeMap, HashMap};
use std::sync::{Arc, Mutex, Weak};
use std::time::{Duration, Instant};

use areal::serve::{
    Control, Grow, Pulled, ReplicaTransport, Request, RoutePolicy, Router, RouterCfg,
    Scheduler, SeqId, ServeCfg, SocketTransport, SocketWorker,
};

const BS: usize = 4;
const GEN: usize = 4;
const MAX_FRAME: usize = 1 << 20;

fn sched() -> Arc<Mutex<Scheduler>> {
    Arc::new(Mutex::new(Scheduler::new(ServeCfg {
        block_size: BS,
        num_blocks: 64,
        max_seqs: 2,
        prefix_cache: true,
    })))
}

/// Family-structured prompts: a shared 16-token family prefix plus a
/// 4-token per-group tail, so probe routing has real cache state to read.
fn family_tokens(gid: u64) -> Vec<i32> {
    let fam = gid % 3;
    let mut t: Vec<i32> = (0..16).map(|i| (fam as i32 * 7 + i) % 23 + 3).collect();
    t.extend((0..4).map(|i| (gid as i32 * 11 + i) % 31 + 3));
    t
}

/// One fleet, either backend. Worker-side serving goes through the same
/// harness code for both; only the delivery hop differs.
struct Fleet {
    router: Arc<Router<()>>,
    scheds: Vec<Arc<Mutex<Scheduler>>>,
    endpoints: Vec<Arc<SocketTransport<()>>>,
    clients: Vec<Option<SocketWorker<()>>>,
    pending_ctrl: Vec<Vec<Control>>,
    next_id: SeqId,
}

fn fleet(socket: bool, w: usize) -> Fleet {
    let scheds: Vec<_> = (0..w).map(|_| sched()).collect();
    let cfg = RouterCfg::new(RoutePolicy::Probe, BS, 0).probe_ttl(u64::MAX);
    if !socket {
        let router = Arc::new(Router::new(w, cfg));
        for (i, s) in scheds.iter().enumerate() {
            router.register_probe(i, s.clone());
        }
        return Fleet {
            router,
            scheds,
            endpoints: Vec::new(),
            clients: Vec::new(),
            pending_ctrl: vec![Vec::new(); w],
            next_id: 0,
        };
    }
    let endpoints: Vec<Arc<SocketTransport<()>>> = (0..w)
        .map(|_| SocketTransport::listen("127.0.0.1:0", MAX_FRAME).unwrap())
        .collect();
    let transports: Vec<Arc<dyn ReplicaTransport<()>>> = endpoints
        .iter()
        .map(|t| Arc::clone(t) as Arc<dyn ReplicaTransport<()>>)
        .collect();
    let router = Arc::new(Router::new_with(transports, cfg));
    for (i, t) in endpoints.iter().enumerate() {
        let weak: Weak<Router<()>> = Arc::downgrade(&router);
        t.set_pull_fn(Arc::new(move |epoch, max_n| match weak.upgrade() {
            Some(r) => r.pull_at(i, epoch, max_n),
            None => Pulled { reqs: Vec::new(), stolen: None },
        }));
    }
    let clients = endpoints
        .iter()
        .map(|t| Some(SocketWorker::connect(&t.local_addr(), MAX_FRAME).unwrap()))
        .collect();
    Fleet {
        router,
        scheds,
        endpoints,
        clients,
        pending_ctrl: vec![Vec::new(); w],
        next_id: 0,
    }
}

impl Fleet {
    fn is_socket(&self) -> bool {
        !self.endpoints.is_empty()
    }

    fn submit(&self, gid: u64, tokens: Vec<i32>) -> usize {
        self.router.submit(Request::new(gid, tokens, ()))
    }

    /// Worker pull. The socket hop ships this replica's fresh probe
    /// snapshot with the frame; the local hop refreshes the transport's
    /// snapshot from the registered probe inside the pull — the same
    /// cadence, so measured routing state stays equivalent.
    fn pull_reqs(&mut self, w: usize, max_n: usize) -> Vec<Request<()>> {
        if self.is_socket() {
            let snap = self.scheds[w].lock().unwrap().probe_snapshot();
            let Some(client) = self.clients[w].as_mut() else {
                return Vec::new();
            };
            match client.pull(max_n, Some(&snap)) {
                Ok(p) if !p.fenced => {
                    self.pending_ctrl[w].extend(p.ctrl);
                    p.reqs
                }
                _ => Vec::new(),
            }
        } else {
            let epoch = self.router.epoch(w);
            self.router.pull_at(w, epoch, max_n).reqs
        }
    }

    fn take_ctrl(&mut self, w: usize) -> Vec<Control> {
        if self.is_socket() {
            let mut out: Vec<Control> = self.pending_ctrl[w].drain(..).collect();
            let snap = self.scheds[w].lock().unwrap().probe_snapshot();
            if let Some(client) = self.clients[w].as_mut() {
                if let Ok(p) = client.pull(0, Some(&snap)) {
                    out.extend(p.ctrl);
                }
            }
            out
        } else {
            self.router.take_control(w)
        }
    }

    fn complete(&mut self, w: usize, tokens: usize) {
        if self.is_socket() {
            if let Some(client) = self.clients[w].as_mut() {
                client.complete(tokens).unwrap();
            }
        } else {
            self.router.complete(w, tokens);
        }
    }

    /// Run pulled requests to completion on replica `w`'s scheduler,
    /// deterministically (sorted-id order), and report completions.
    fn drive(&mut self, w: usize, reqs: Vec<Request<()>>) {
        if reqs.is_empty() {
            return;
        }
        let mut items = Vec::new();
        for q in reqs {
            let id = self.next_id;
            self.next_id += 1;
            items.push((id, q.tokens));
        }
        let mut completed: Vec<usize> = Vec::new();
        {
            let sched = Arc::clone(&self.scheds[w]);
            let mut s = sched.lock().unwrap();
            let mut targets: BTreeMap<SeqId, (usize, usize)> = BTreeMap::new();
            let mut active: BTreeMap<SeqId, Vec<i32>> = BTreeMap::new();
            for (id, tokens) in items {
                let plen = tokens.len();
                assert!(s.submit(id, tokens));
                targets.insert(id, (plen + GEN, plen));
            }
            loop {
                for a in s.schedule() {
                    s.note_prefilled(a.id, &a.tokens);
                    active.insert(a.id, a.tokens);
                }
                if active.is_empty() {
                    assert_eq!(s.waiting_len(), 0, "replica {w} starved");
                    break;
                }
                let ids: Vec<SeqId> = active.keys().copied().collect();
                for id in ids {
                    let Some(mut t) = active.remove(&id) else { continue };
                    t.push((id % 41) as i32 + 3);
                    loop {
                        match s.grow_to(id, t.len()) {
                            Grow::Ok => break,
                            Grow::Preempt(v) => {
                                let vt = active.remove(&v).expect("victim active");
                                s.preempt(v, &vt, vt.len());
                            }
                            Grow::Fail => panic!("pool too small"),
                        }
                    }
                    let (target, plen) = targets[&id];
                    if t.len() >= target {
                        s.finish(id, &t, t.len());
                        completed.push(plen);
                    } else {
                        active.insert(id, t);
                    }
                }
            }
        }
        for plen in completed {
            self.complete(w, plen);
        }
    }

    /// Serve replica `w` until its inbox is dry. The final empty pull is
    /// the snapshot heartbeat on both backends.
    fn serve_all(&mut self, w: usize) {
        loop {
            let reqs = self.pull_reqs(w, 64);
            if reqs.is_empty() {
                break;
            }
            self.drive(w, reqs);
        }
    }

    fn shutdown(&mut self) {
        for c in self.clients.iter_mut() {
            if let Some(c) = c.as_mut() {
                c.bye();
            }
        }
        for e in &self.endpoints {
            e.shutdown();
        }
    }
}

fn run_trace(socket: bool) -> (Vec<usize>, u64, u64) {
    const W: usize = 2;
    let mut f = fleet(socket, W);
    let mut placements = Vec::new();
    for gid in 0..12u64 {
        let tokens = family_tokens(gid);
        for _ in 0..4 {
            placements.push(f.submit(gid, tokens.clone()));
        }
        for w in 0..W {
            f.serve_all(w);
        }
    }
    let mut computed = 0u64;
    let mut cached = 0u64;
    for s in &f.scheds {
        let s = s.lock().unwrap();
        computed += s.prefill_tokens_computed;
        cached += s.prefill_tokens_cached;
    }
    f.shutdown();
    (placements, computed, cached)
}

#[test]
fn probe_routing_decisions_identical_across_backends() {
    let (local_placed, local_computed, local_cached) = run_trace(false);
    let (socket_placed, socket_computed, socket_cached) = run_trace(true);
    assert_eq!(
        local_placed, socket_placed,
        "probe placement trace diverged between transports"
    );
    assert_eq!(
        (local_computed, local_cached),
        (socket_computed, socket_cached),
        "prefill accounting diverged between transports"
    );
    assert!(local_cached > 0, "the trace must exercise the prefix cache");
    assert!(
        local_placed.iter().any(|&p| p == 0) && local_placed.iter().any(|&p| p == 1),
        "the trace must exercise both replicas: {local_placed:?}"
    );
}

#[test]
fn control_fanout_reaches_every_replica_on_both_backends() {
    for socket in [false, true] {
        let mut f = fleet(socket, 3);
        f.router.broadcast(Control::UpdateWeights(7));
        f.router.broadcast(Control::Drain);
        for w in 0..3 {
            assert_eq!(
                f.take_ctrl(w),
                vec![Control::UpdateWeights(7), Control::Drain],
                "socket={socket} replica {w}"
            );
            assert!(f.take_ctrl(w).is_empty(), "control is consumed (socket={socket})");
        }
        f.shutdown();
    }
}

#[test]
fn replica_loss_salvages_with_zero_lost_requests_on_both_backends() {
    for socket in [false, true] {
        let mut f = fleet(socket, 3);
        let mut submitted: HashMap<u64, usize> = HashMap::new();
        for gid in 0..6u64 {
            let tokens = family_tokens(gid);
            for _ in 0..4 {
                f.submit(gid, tokens.clone());
                *submitted.entry(gid).or_default() += 1;
            }
        }
        let before = f.router.queued_total();
        assert_eq!(before, 24);
        let victim_q = f.router.queued(1);
        let requeued = f.router.remove_replica(1).expect("removable");
        assert_eq!(requeued, victim_q, "socket={socket}");
        assert_eq!(f.router.queued_total(), before, "zero lost (socket={socket})");
        if socket {
            // the victim's worker is fenced mid-stream: reconnect-aware
            // fencing refuses its pulls
            let snap = f.scheds[1].lock().unwrap().probe_snapshot();
            let p = f.clients[1].as_mut().unwrap().pull(8, Some(&snap)).unwrap();
            assert!(p.fenced, "removed socket replica must be fenced");
            f.clients[1] = None;
        }
        // survivors serve everything; every GRPO group stays whole
        let mut served: HashMap<u64, usize> = HashMap::new();
        for w in [0usize, 2] {
            loop {
                let reqs = f.pull_reqs(w, 64);
                if reqs.is_empty() {
                    break;
                }
                for q in &reqs {
                    *served.entry(q.group).or_default() += 1;
                }
                f.drive(w, reqs);
            }
        }
        assert_eq!(served, submitted, "partial GRPO group after removal (socket={socket})");
        f.shutdown();
    }
}

#[test]
fn mid_stream_replica_failure_loses_nothing_on_both_backends() {
    for socket in [false, true] {
        let mut f = fleet(socket, 2);
        if socket {
            // disconnect supervision, wired as system.rs wires it: a
            // dropped connection retires the replica through the standard
            // salvage path, fenced by the connection's epoch
            let weak = Arc::downgrade(&f.router);
            f.endpoints[0].set_disconnect_fn(Arc::new(move |epoch, orphans| {
                if let Some(r) = weak.upgrade() {
                    let _ = r.remove_replica_at(0, epoch);
                    for q in orphans {
                        r.submit(q);
                    }
                }
            }));
        }
        let mut submitted: HashMap<u64, usize> = HashMap::new();
        for gid in 0..6u64 {
            let tokens = family_tokens(gid);
            for _ in 0..4 {
                f.submit(gid, tokens.clone());
                *submitted.entry(gid).or_default() += 1;
            }
        }
        let total = f.router.queued_total();
        // replica 0 pulls a batch "in flight", then dies mid-stream
        let inflight = f.pull_reqs(0, 3);
        if socket {
            f.clients[0] = None; // dropped without bye
            let t0 = Instant::now();
            while f.router.is_alive(0) {
                assert!(
                    t0.elapsed() < Duration::from_secs(5),
                    "disconnect supervision never retired the replica"
                );
                std::thread::sleep(Duration::from_millis(5));
            }
        } else {
            f.router.remove_replica(0).expect("removable");
        }
        // the dying worker's salvage contract (rollout.rs does this via
        // GenEngine::salvage_requests): in-flight requests return through
        // the router
        for q in inflight {
            f.router.submit(q);
        }
        assert_eq!(f.router.queued_total(), total, "zero lost (socket={socket})");
        // the survivor serves every group whole
        let mut served: HashMap<u64, usize> = HashMap::new();
        loop {
            let reqs = f.pull_reqs(1, 64);
            if reqs.is_empty() {
                break;
            }
            for q in &reqs {
                *served.entry(q.group).or_default() += 1;
            }
            f.drive(1, reqs);
        }
        assert_eq!(
            served, submitted,
            "partial group after mid-stream loss (socket={socket})"
        );
        f.shutdown();
    }
}
