//! End-to-end integration: the full AReaL topology (controller + rollout
//! workers + reward service + trainer + param server) on the nano tier.
//! Requires `make artifacts`.

use std::path::PathBuf;

use areal::config::{Config, Mode};
use areal::coordinator::{Event, System};
use areal::runtime::artifacts::test_artifacts_dir;

macro_rules! require_artifacts {
    () => {
        if test_artifacts_dir().is_none() {
            eprintln!("skipping: AOT artifacts not built (run `make artifacts`)");
            return;
        }
    };
}

fn base_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.artifacts_dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    cfg.tier = "nano".into();
    cfg.task = "sort".into();
    cfg.level_lo = 2;
    cfg.level_hi = 3;
    cfg.group_size = 4;
    cfg.global_batch = 8;
    cfg.ppo_minibatches = 2;
    cfg.ppo_steps = 3;
    cfg.n_rollout_workers = 1;
    cfg.reward_threads = 1;
    cfg.sft_steps = 2;
    cfg.eval_samples = 0;
    cfg.token_budget = 256;
    // keep these tests hermetic: no exporter threads, no metrics_live.jsonl
    // in the working tree (the live telemetry path is covered end-to-end by
    // rust/tests/metrics_live.rs against a temp out_dir)
    cfg.metrics = false;
    cfg.validate().unwrap();
    cfg
}

#[test]
fn async_mode_runs_end_to_end() {
    require_artifacts!();
    let mut cfg = base_cfg();
    cfg.mode = Mode::Async;
    cfg.max_staleness = Some(4);
    let sys = System::build(cfg).expect("build (run `make artifacts` first)");
    let report = sys.run().expect("run");
    assert_eq!(report.steps.len(), 3);
    // versions are monotone 1..=3
    let versions: Vec<u64> = report.steps.iter().map(|m| m.version).collect();
    assert_eq!(versions, vec![1, 2, 3]);
    // every step consumed a full batch
    for m in &report.steps {
        assert!(m.tokens_consumed > 0);
        assert!(m.mean_completion_len > 0.0);
        assert!(m.grad_norm.is_finite());
        assert!(m.max_staleness <= 4, "Eq.3 violated: {}", m.max_staleness);
    }
    assert!(report.gen_tokens > 0);
    assert!(report.train_tokens > 0);
    assert!(report.effective_tps > 0.0);
    // trajectories were verified by the reward service
    let done = report.trace.count(|e| matches!(e, Event::RewardDone { .. }));
    assert!(done >= 3 * 8, "{done} rewards for 24 consumed trajectories");
}

#[test]
fn sync_mode_has_zero_staleness() {
    require_artifacts!();
    let mut cfg = base_cfg();
    cfg.mode = Mode::Sync;
    cfg.ppo_steps = 2;
    let sys = System::build(cfg).expect("build");
    let report = sys.run().expect("run");
    assert_eq!(report.steps.len(), 2);
    for m in &report.steps {
        assert_eq!(m.max_staleness, 0, "sync mode must train on-policy");
        assert_eq!(m.interrupted_frac, 0.0, "sync mode never interrupts");
    }
}

#[test]
fn async_interruptions_produce_multi_segment_trajectories() {
    require_artifacts!();
    let mut cfg = base_cfg();
    cfg.mode = Mode::Async;
    cfg.max_staleness = Some(8);
    cfg.ppo_steps = 4;
    cfg.level_lo = 3;
    cfg.level_hi = 3; // longer outputs -> more chance of mid-flight updates
    let sys = System::build(cfg).expect("build");
    let report = sys.run().expect("run");
    // weight updates happened while generation was in flight at least once
    let interrupts = report.trace.count(|e| matches!(e, Event::Interrupt { .. }));
    let any_multi = report.steps.iter().any(|m| m.interrupted_frac > 0.0);
    assert!(
        interrupts > 0 || any_multi,
        "async run with 4 steps should interrupt at least once \
         (interrupts={interrupts})"
    );
}
