//! areal-lint self-test: the seeded bad fixtures are flagged with the
//! right rule at the right file:line, the compliant fixtures pass, and —
//! the actual gate — the real tree is clean.

use std::path::{Path, PathBuf};

use areal::lint;

fn fixtures(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/lint_fixtures")
        .join(name)
}

fn has(findings: &[lint::Finding], rule: &str, file: &str, line: usize) -> bool {
    findings
        .iter()
        .any(|f| f.rule == rule && f.file == file && f.line == line)
}

#[test]
fn bad_fixtures_are_flagged_with_file_and_line() {
    let findings = lint::lint_tree(&fixtures("bad_tree"));
    let report = lint::render(&findings);
    let fx = "rust/src/serve/fixture.rs";
    // undeclared lock edge: beta acquired, then alpha under beta's guard
    assert!(has(&findings, "lock-order", fx, 9), "missing lock edge finding:\n{report}");
    // bare unwrap
    assert!(has(&findings, "panic", fx, 13), "missing panic finding:\n{report}");
    // unchecked index
    assert!(has(&findings, "index", fx, 17), "missing index finding:\n{report}");
    // bare-index fence call
    assert!(has(&findings, "epoch-fence", fx, 21), "missing fence finding:\n{report}");
    // channel send under a live guard
    assert!(has(&findings, "lock-order", fx, 26), "missing send-under-guard finding:\n{report}");
    // undocumented + sim-absent metric
    assert!(has(&findings, "metric-doc", fx, 30), "missing metric-doc finding:\n{report}");
    assert!(has(&findings, "metric-sim", fx, 30), "missing metric-sim finding:\n{report}");
    // discarded reopen epoch
    assert!(has(&findings, "epoch-fence", fx, 34), "missing reopen finding:\n{report}");
    // missing Event CSV arm + catch-all
    let tr = "rust/src/coordinator/trace.rs";
    assert!(has(&findings, "event-csv", tr, 5), "missing event arm finding:\n{report}");
    assert!(has(&findings, "event-csv", tr, 14), "missing catch-all finding:\n{report}");
    // undocumented config key
    assert!(
        has(&findings, "config-doc", "rust/src/config.rs", 6),
        "missing config-doc finding:\n{report}"
    );
}

#[test]
fn clean_fixtures_pass() {
    let findings = lint::lint_tree(&fixtures("clean_tree"));
    assert!(
        findings.is_empty(),
        "clean fixture tree should have no findings:\n{}",
        lint::render(&findings)
    );
}

#[test]
fn real_tree_is_clean() {
    let findings = lint::lint_tree(Path::new(env!("CARGO_MANIFEST_DIR")));
    assert!(
        findings.is_empty(),
        "the real tree must lint clean — fix the code or annotate the invariant:\n{}",
        lint::render(&findings)
    );
}
