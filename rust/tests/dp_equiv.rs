//! Elastic DP training equivalence (DESIGN.md §11): the split
//! grad_step → tree-reduce → apply_grads path must train the *same model*
//! as the legacy fused `train_step` — bitwise at dp=1, within float
//! tolerance at dp>1 — and must survive a rank dying mid-step with zero
//! lost work. Requires `make artifacts`.

use std::sync::Arc;

use areal::config::BaselineCfg;
use areal::coordinator::dp::{self, ShardOutput, ShardTask};
use areal::coordinator::{DpPool, ParamServer, Trace, Trainer, TrainerCfg, Trajectory};
use areal::runtime::artifacts::test_artifacts_dir;
use areal::runtime::{Engine, HostTensor, Manifest, ParamSet, TrainState};
use areal::tasks::Prompt;

macro_rules! require_artifacts {
    () => {
        if test_artifacts_dir().is_none() {
            eprintln!("skipping: AOT artifacts not built (run `make artifacts`)");
            return;
        }
    };
}

fn nano_engine() -> Arc<Engine> {
    let dir = test_artifacts_dir().expect("gated by require_artifacts!");
    let m = Manifest::load(&dir).expect("manifest load");
    let spec = m.tier("nano").expect("nano tier");
    Arc::new(Engine::load(spec).expect("engine load"))
}

/// Two trainers must start from identical state to be comparable, so the
/// seed is fixed; they share one engine so every executable run goes
/// through the same compiled artifact.
fn make_trainer(engine: &Arc<Engine>, train_dp: usize, train_dp_max: usize) -> Trainer {
    let params = ParamSet::init(engine, [7, 0x9e37]).expect("init params");
    let server = ParamServer::new(Arc::clone(&params));
    let state = TrainState::fresh(&engine.spec, params).expect("fresh state");
    Trainer::new(
        Arc::clone(engine),
        state,
        server,
        TrainerCfg {
            global_batch: 8,
            ppo_minibatches: 2,
            lr: 1e-2,
            decoupled: true,
            dynamic_batching: true,
            token_budget: 256,
            train_dp,
            train_dp_max,
        },
        BaselineCfg::GroupMean,
    )
}

/// Deterministic synthetic batch: 4 GRPO groups of 2, mixed rewards so
/// group-mean advantages are non-zero, varied lengths so the shard split
/// has real balancing to do. Nano tier: vocab 48, max_seq 64.
fn synth_batch() -> Vec<Trajectory> {
    let mut x: u64 = 0x243F_6A88_85A3_08D3;
    let mut rng = move || {
        x = x
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        (x >> 33) as u32
    };
    (0..8usize)
        .map(|i| {
            let prompt_len = 4;
            let clen = 8 + (i * 5) % 17;
            let tokens: Vec<i32> = (0..prompt_len + clen)
                .map(|_| (rng() % 46 + 1) as i32)
                .collect();
            let behav_logp: Vec<f32> =
                (0..clen).map(|_| -0.05 - (rng() % 100) as f32 * 0.01).collect();
            Trajectory {
                prompt: Prompt {
                    text: format!("synthetic {i}"),
                    meta: String::new(),
                    level: 1,
                    group: (i / 2) as u64,
                },
                tokens,
                prompt_len,
                behav_logp,
                segments: vec![(0, clen)],
                version_born: 0,
                reward: if i % 2 == 0 { 5.0 } else { -5.0 },
                correct: i % 2 == 0,
                truncated: false,
                worker: 0,
                span: Default::default(),
            }
        })
        .collect()
}

fn params_f32(t: &Trainer) -> Vec<Vec<f32>> {
    t.state
        .params
        .tensors
        .iter()
        .map(|l| {
            HostTensor::from_literal(l.lit())
                .expect("host readback")
                .as_f32()
                .expect("f32 params")
                .to_vec()
        })
        .collect()
}

fn max_abs_diff(a: &[Vec<f32>], b: &[Vec<f32>]) -> f32 {
    let mut worst = 0f32;
    for (ta, tb) in a.iter().zip(b) {
        assert_eq!(ta.len(), tb.len(), "param tensor shape mismatch");
        for (&x, &y) in ta.iter().zip(tb) {
            worst = worst.max((x - y).abs());
        }
    }
    worst
}

fn bits_equal(a: &[Vec<f32>], b: &[Vec<f32>]) -> bool {
    a.iter().zip(b).all(|(ta, tb)| {
        ta.len() == tb.len()
            && ta.iter().zip(tb).all(|(x, y)| x.to_bits() == y.to_bits())
    })
}

#[test]
fn dp1_is_bitwise_identical_to_fused() {
    require_artifacts!();
    let engine = nano_engine();
    let mut fused = make_trainer(&engine, 0, 0);
    let mut dp1 = make_trainer(&engine, 1, 0);
    let trace = Trace::new(false);
    let mf = fused.ppo_step(synth_batch(), 0, &trace).expect("fused step");
    let md = dp1.ppo_step(synth_batch(), 0, &trace).expect("dp=1 step");
    assert_eq!(md.dp, 1);
    // single shard: weight exactly 1.0, no reduction arithmetic — the
    // metric vector and the updated parameters must match to the bit
    for (name, a, b) in [
        ("loss", mf.loss, md.loss),
        ("clip_frac", mf.clip_frac, md.clip_frac),
        ("ratio_mean", mf.ratio_mean, md.ratio_mean),
        ("approx_kl", mf.approx_kl, md.approx_kl),
        ("grad_norm", mf.grad_norm, md.grad_norm),
        ("w_mean", mf.w_mean, md.w_mean),
    ] {
        assert_eq!(a.to_bits(), b.to_bits(), "{name}: fused {a} vs dp1 {b}");
    }
    assert_eq!(mf.tokens_consumed, md.tokens_consumed);
    assert!(
        bits_equal(&params_f32(&fused), &params_f32(&dp1)),
        "dp=1 must produce bitwise-identical parameters to the fused path"
    );
}

#[test]
fn dp2_matches_fused_within_tolerance() {
    require_artifacts!();
    let engine = nano_engine();
    let mut fused = make_trainer(&engine, 0, 0);
    let mut dp2 = make_trainer(&engine, 2, 0);
    let trace = Trace::new(false);
    let mf = fused.ppo_step(synth_batch(), 0, &trace).expect("fused step");
    let md = dp2.ppo_step(synth_batch(), 0, &trace).expect("dp=2 step");
    assert_eq!(md.dp, 2, "both minibatches should shard 2-way");
    // sharded grads are locally normalized then token-weight combined —
    // same mathematical mean, different float summation order
    assert!(
        (mf.loss - md.loss).abs() < 1e-3,
        "loss: fused {} vs dp2 {}",
        mf.loss,
        md.loss
    );
    assert!(
        (mf.grad_norm - md.grad_norm).abs() < 1e-3 * mf.grad_norm.abs().max(1.0),
        "grad_norm: fused {} vs dp2 {}",
        mf.grad_norm,
        md.grad_norm
    );
    assert!(
        (mf.approx_kl - md.approx_kl).abs() < 1e-3,
        "approx_kl: fused {} vs dp2 {}",
        mf.approx_kl,
        md.approx_kl
    );
    assert_eq!(mf.tokens_consumed, md.tokens_consumed);
    let diff = max_abs_diff(&params_f32(&fused), &params_f32(&dp2));
    assert!(
        diff < 1e-4,
        "dp=2 parameters drift {diff} from fused after one step"
    );
}

#[test]
fn worker_loss_mid_step_loses_nothing() {
    require_artifacts!();
    let engine = nano_engine();
    let trace = Trace::new(false);

    // reference: same degree, no pool — lead computes every shard inline
    let mut reference = make_trainer(&engine, 2, 0);
    let mr = reference.ppo_step(synth_batch(), 0, &trace).expect("ref step");

    // pooled run with a rank whose engine cannot run grad_step: every
    // shard it claims fails and is requeued, and the lead recomputes
    let mut pooled = make_trainer(&engine, 2, 4);
    let pool = Arc::new(DpPool::new());
    pooled.set_dp_pool(Arc::clone(&pool));
    let broken =
        Engine::load_subset(&engine.spec, Some(&["init"])).expect("subset engine");
    let pool2 = Arc::clone(&pool);
    let handle = std::thread::spawn(move || {
        let rank = pool2.register();
        let mut attempts = 0usize;
        // bounded attempts so the failing rank cannot starve the lead
        while !rank.pool_closed() && attempts < 8 {
            if rank.serve_one(&broken) {
                attempts += 1;
            } else {
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
        }
        attempts
    });
    // wait for the rank to register so dp_degree sees it
    for _ in 0..1000 {
        if pool.workers() == 1 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    assert_eq!(pool.workers(), 1, "rank never registered");
    let mp = pooled.ppo_step(synth_batch(), 0, &trace).expect("pooled step");
    pool.close();
    let attempts = handle.join().expect("worker thread");
    eprintln!("broken rank claimed {attempts} shards (all requeued)");

    // zero loss: every shard was computed (by the lead, after requeue) and
    // the result is identical to the no-pool run — shard set, reduction
    // order, and engine are all the same
    assert_eq!(mp.dp, mr.dp);
    assert_eq!(mp.tokens_consumed, mr.tokens_consumed);
    assert_eq!(
        mp.loss.to_bits(),
        mr.loss.to_bits(),
        "loss: pooled {} vs reference {}",
        mp.loss,
        mr.loss
    );
    assert_eq!(mp.grad_norm.to_bits(), mr.grad_norm.to_bits());
    assert!(
        bits_equal(&params_f32(&pooled), &params_f32(&reference)),
        "a dying rank must not change the trained model"
    );
}

#[test]
fn tree_reduction_is_arrival_order_invariant_on_real_grads() {
    require_artifacts!();
    let dir = test_artifacts_dir().expect("gated");
    let m = Manifest::load(&dir).expect("manifest load");
    let spec = m.tier("nano").expect("nano tier");
    let engine =
        Engine::load_subset(spec, Some(&["init", "grad_step"])).expect("engine");
    let params = ParamSet::init(&engine, [3, 5]).expect("init");
    let bt = engine.spec.config.train_batch;
    let t = engine.spec.config.max_seq;

    // three hand-built shards with different contents and token counts
    let mk = |idx: usize| -> ShardTask {
        let mut tokens = vec![0i32; bt * t];
        let mut mask = vec![0f32; bt * t];
        let mut adv = vec![0f32; bt * t];
        let mut behav = vec![0f32; bt * t];
        let mut prox = vec![0f32; bt * t];
        for row in 0..2usize {
            let len = 12 + 3 * idx + row;
            for pos in 0..len {
                tokens[row * t + pos] = ((pos * 7 + idx * 13 + row * 29) % 46 + 1) as i32;
            }
            for pos in 4..len {
                mask[row * t + pos] = 1.0;
                adv[row * t + pos] = 0.5 - idx as f32 * 0.25;
                behav[row * t + pos] = -0.3;
                prox[row * t + pos] = -0.25;
            }
        }
        ShardTask {
            shard_idx: idx,
            entry: "grad_step",
            params: Arc::clone(&params),
            tokens: HostTensor::i32(vec![bt, t], tokens),
            mask: HostTensor::f32(vec![bt, t], mask),
            adv: HostTensor::f32(vec![bt, t], adv),
            behav: HostTensor::f32(vec![bt, t], behav),
            prox: HostTensor::f32(vec![bt, t], prox),
        }
    };
    let run = |idx: usize| dp::run_shard(&engine, &mk(idx)).expect("run_shard");
    let reduce_in_order = |order: &[usize]| -> (Vec<Vec<f32>>, Vec<f32>) {
        let shards: Vec<ShardOutput> = order.iter().map(|&i| run(i)).collect();
        dp::reduce_grads(shards)
    };
    let (ga, ma) = reduce_in_order(&[0, 1, 2]);
    let (gb, mb) = reduce_in_order(&[2, 0, 1]);
    let (gc, mc) = reduce_in_order(&[1, 2, 0]);
    assert!(
        bits_equal(&ga, &gb) && bits_equal(&ga, &gc),
        "combined gradient must be bitwise independent of arrival order"
    );
    assert_eq!(
        ma.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        mb.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
    );
    assert_eq!(
        ma.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        mc.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
    );
    assert!(ma[dp::METRIC_N_TOKENS] > 0.0, "shards carried trained tokens");
}
