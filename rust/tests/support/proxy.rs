//! Frame-aware fault-injection proxy for the socket transport test plane
//! (ISSUE 10): a TCP shim that sits between a `SocketWorker` and a
//! `SocketTransport` endpoint, forwards the length-prefixed frames of the
//! wire protocol in both directions, and injects faults on command —
//! sever the link mid-frame, delay frames, truncate one frame's body,
//! duplicate one frame. The coordinator sees an ordinary (misbehaving)
//! client; the client sees an ordinary (flaky) coordinator — exactly the
//! failure surface a multi-node deployment has and loopback tests
//! otherwise never exercise.
//!
//! Fault switches apply to the downstream direction (endpoint → client):
//! that is where pulled requests, weight chunks and result acks travel,
//! i.e. where loss and duplication have observable protocol consequences.

use std::io::{Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Shared fault switches. Flip them from the test while traffic flows.
#[derive(Default)]
pub struct Controls {
    /// added latency per forwarded frame, in milliseconds (both directions)
    pub delay_ms: AtomicU64,
    /// drop every live connection now; new connections are still accepted
    /// once the flag is cleared
    pub sever: AtomicBool,
    /// truncate the next downstream frame mid-body, then drop the link
    /// (a torn write: length prefix promises more bytes than arrive)
    pub truncate_next: AtomicBool,
    /// send the next downstream frame twice
    pub duplicate_next: AtomicBool,
    /// downstream frames forwarded intact (progress accounting)
    pub frames_down: AtomicUsize,
}

pub struct FaultProxy {
    addr: String,
    pub ctl: Arc<Controls>,
    live: Arc<Mutex<Vec<TcpStream>>>,
}

impl FaultProxy {
    /// Start a proxy in front of `upstream` (an endpoint's
    /// `local_addr()`). Listens on an ephemeral loopback port.
    pub fn start(upstream: &str) -> FaultProxy {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind proxy");
        let addr = listener.local_addr().expect("proxy addr").to_string();
        let ctl = Arc::new(Controls::default());
        let live: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
        let upstream = upstream.to_string();
        let ctl_l = Arc::clone(&ctl);
        let live_l = Arc::clone(&live);
        std::thread::spawn(move || {
            for conn in listener.incoming() {
                let Ok(client) = conn else { break };
                let Ok(server) = TcpStream::connect(&upstream) else {
                    let _ = client.shutdown(Shutdown::Both);
                    continue;
                };
                client.set_nodelay(true).ok();
                server.set_nodelay(true).ok();
                {
                    let mut l = live_l.lock().unwrap();
                    l.push(client.try_clone().expect("clone client"));
                    l.push(server.try_clone().expect("clone server"));
                }
                // upstream pump: client -> endpoint, no fault injection
                let c_up = client.try_clone().expect("clone");
                let s_up = server.try_clone().expect("clone");
                let ctl_up = Arc::clone(&ctl_l);
                std::thread::spawn(move || pump(c_up, s_up, ctl_up, false));
                // downstream pump: endpoint -> client, faults apply here
                let ctl_down = Arc::clone(&ctl_l);
                std::thread::spawn(move || pump(server, client, ctl_down, true));
            }
        });
        FaultProxy { addr, ctl, live }
    }

    /// Address clients should dial instead of the endpoint's.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Cut every live connection now (both directions, mid-whatever), and
    /// let subsequent reconnects pass again.
    pub fn sever_now(&self) {
        self.ctl.sever.store(true, Ordering::SeqCst);
        let mut l = self.live.lock().unwrap();
        for s in l.drain(..) {
            let _ = s.shutdown(Shutdown::Both);
        }
        self.ctl.sever.store(false, Ordering::SeqCst);
    }
}

/// Read exactly one `u32`-BE length-prefixed frame. None on EOF/error.
fn read_frame(s: &mut TcpStream) -> Option<Vec<u8>> {
    let mut len = [0u8; 4];
    s.read_exact(&mut len).ok()?;
    let n = u32::from_be_bytes(len) as usize;
    if n > 64 << 20 {
        return None; // corrupt length: drop the link
    }
    let mut body = vec![0u8; n];
    s.read_exact(&mut body).ok()?;
    let mut frame = len.to_vec();
    frame.extend_from_slice(&body);
    Some(frame)
}

fn pump(mut from: TcpStream, mut to: TcpStream, ctl: Arc<Controls>, down: bool) {
    while let Some(frame) = read_frame(&mut from) {
        let d = ctl.delay_ms.load(Ordering::Relaxed);
        if d > 0 {
            std::thread::sleep(Duration::from_millis(d));
        }
        if ctl.sever.load(Ordering::SeqCst) {
            break;
        }
        if down && ctl.truncate_next.swap(false, Ordering::SeqCst) {
            // torn write: ship the length prefix and half the body, then
            // kill the link — the reader's read_exact must error, never
            // deliver a short frame as if it were whole
            let cut = 4 + (frame.len() - 4) / 2;
            let _ = to.write_all(&frame[..cut]);
            break;
        }
        if down && ctl.duplicate_next.swap(false, Ordering::SeqCst) {
            if to.write_all(&frame).is_err() {
                break;
            }
        }
        if to.write_all(&frame).is_err() {
            break;
        }
        if down {
            ctl.frames_down.fetch_add(1, Ordering::SeqCst);
        }
    }
    let _ = from.shutdown(Shutdown::Both);
    let _ = to.shutdown(Shutdown::Both);
}
