// Compliant fixture: the same shapes as bad_tree, written the way the
// lint wants them — declared lock order, annotated invariants, fenced
// epochs, documented metrics.
pub struct Fx;

impl Fx {
    fn good_lock_order(&self) {
        let g = self.alpha.plock();
        let h = self.beta.plock();
    }

    fn good_unwrap(&self) {
        let v = self.maybe.unwrap(); // areal-lint: allow(panic, reason="set at construction")
    }

    fn good_index(&self) {
        let x = &self.items[1..3];
        let y = self.items[0];
    }

    fn good_fence(&self, slot: usize, epoch: u64) {
        self.t.close_salvage_at(epoch);
    }

    fn good_send(&self) {
        let msg = {
            let g = self.alpha.plock();
            g.front()
        };
        self.tx.send(msg);
    }

    fn good_metric(&self) {
        metrics::inc("areal_documented_total", 1);
    }

    fn good_reopen(&self) -> u64 {
        let epoch = self.t.reopen();
        epoch
    }
}
