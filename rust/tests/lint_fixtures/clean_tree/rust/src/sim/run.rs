// Fixture simulator: emits the documented metric.
pub fn run() {
    metrics::inc("areal_documented_total", 1);
}
