pub struct Config;

impl Config {
    pub const KEYS: &'static [(&'static str, &'static str)] = &[
        ("documented_key", "1"),
        ("other_key", "2"),
    ];
}
