// Compliant drift fixture: exhaustive to_csv with no catch-all, every
// kind string asserted by a decode test.
pub enum Event {
    Alpha { t: f64 },
    Beta { t: f64 },
}

pub struct Tracer;

impl Tracer {
    fn to_csv(&self, e: &Event) -> String {
        match e {
            Event::Alpha { t } => row(*t, "alpha_kind"),
            Event::Beta { t } => row(*t, "beta_kind"),
        }
    }
}

fn row(t: f64, kind: &str) -> String {
    format!("{t:.6},{kind}\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_roundtrip() {
        let tr = Tracer;
        let a = tr.to_csv(&Event::Alpha { t: 1.0 });
        let b = tr.to_csv(&Event::Beta { t: 2.0 });
        assert!(a.contains("alpha_kind"));
        assert!(b.contains("beta_kind"));
    }
}
