pub struct Config;

impl Config {
    pub const KEYS: &'static [(&'static str, &'static str)] = &[
        ("documented_key", "1"),
        ("mystery_key", "2"),
    ];
}
