// Seeded-violation fixture for areal-lint's self-test. Every finding's
// file:line is asserted by rust/tests/lint_self.rs — keep line numbers
// stable when editing.
pub struct Fx;

impl Fx {
    fn bad_lock_order(&self) {
        let g = self.beta.plock();
        let h = self.alpha.plock();
    }

    fn bad_unwrap(&self) {
        let v = self.maybe.unwrap();
    }

    fn bad_index(&self, i: usize) {
        let x = self.items[i];
    }

    fn bad_fence(&self, slot: usize) {
        self.t.close_salvage_at(slot);
    }

    fn bad_send(&self) {
        let g = self.beta.plock();
        self.tx.send(1);
    }

    fn bad_metric(&self) {
        metrics::inc("areal_phantom_total", 1);
    }

    fn bad_reopen(&self) {
        self.t.reopen();
    }
}
