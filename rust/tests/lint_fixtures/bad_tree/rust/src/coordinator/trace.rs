// Drift fixture: Event::Beta has no to_csv arm and the match hides the
// gap behind a catch-all. Line numbers are asserted by lint_self.rs.
pub enum Event {
    Alpha { t: f64 },
    Beta { t: f64 },
}

pub struct Tracer;

impl Tracer {
    fn to_csv(&self, e: &Event) -> String {
        match e {
            Event::Alpha { t } => format!("{t},alpha_kind"),
            _ => String::new(),
        }
    }
}
